//! Checkpointing: persist/restore model parameters + run metadata so long
//! pretraining runs survive restarts (and so trained models can be handed
//! to downstream tools). Format: `<stem>.bin` (f32 LE, layer order) +
//! `<stem>.json` (metadata incl. shape table for validation).
//!
//! Saves are atomic: both files are written to `.tmp` siblings and moved
//! into place with `rename`, so a kill mid-save never leaves a torn
//! checkpoint for `--resume` to half-load — the stem either holds the
//! previous complete checkpoint or the new one. The weights commit first;
//! the metadata (whose `step` drives resume) commits second, so the
//! worst-case crash window resumes one save earlier, never ahead of the
//! weights. The seed is stored as a decimal string: JSON numbers travel as
//! f64 here and would silently corrupt seeds above 2^53.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::linalg::matrix::{Layers, Matrix};
use crate::util::json::{Json, JsonObj};

/// Metadata stored alongside the weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub step: usize,
    pub eval_loss: f64,
    pub comp: String,
    pub seed: u64,
    pub shapes: Vec<(usize, usize)>,
}

/// Write `<stem>.bin` + `<stem>.json` atomically (tmp + rename).
pub fn save(stem: impl AsRef<Path>, params: &Layers, meta: &CheckpointMeta) -> Result<()> {
    let stem = stem.as_ref();
    if let Some(parent) = stem.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut bytes = Vec::with_capacity(params.iter().map(|p| p.numel() * 4).sum());
    for p in params {
        for v in &p.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    let shapes: Vec<Json> = params
        .iter()
        .map(|p| Json::Arr(vec![Json::Num(p.rows as f64), Json::Num(p.cols as f64)]))
        .collect();
    let j = JsonObj::new()
        .put("step", meta.step)
        .put("eval_loss", meta.eval_loss)
        .put("comp", meta.comp.as_str())
        .put("seed", meta.seed.to_string().as_str())
        .put("shapes", Json::Arr(shapes))
        .build();

    let bin = stem.with_extension("bin");
    let bin_tmp = stem.with_extension("bin.tmp");
    let json = stem.with_extension("json");
    let json_tmp = stem.with_extension("json.tmp");
    std::fs::write(&bin_tmp, &bytes)
        .with_context(|| format!("writing {}", bin_tmp.display()))?;
    std::fs::write(&json_tmp, j.to_string())
        .with_context(|| format!("writing {}", json_tmp.display()))?;
    // weights first, metadata second: a crash between the renames resumes
    // from the previous step count, never ahead of the committed weights
    std::fs::rename(&bin_tmp, &bin)
        .with_context(|| format!("committing {}", bin.display()))?;
    std::fs::rename(&json_tmp, &json)
        .with_context(|| format!("committing {}", json.display()))?;
    Ok(())
}

/// Read a checkpoint; validates the byte count against the shape table.
/// Malformed metadata returns a clean `Err` naming the offending field —
/// never a panic, never silently-zero shapes.
pub fn load(stem: impl AsRef<Path>) -> Result<(Layers, CheckpointMeta)> {
    let stem = stem.as_ref();
    let meta_text = std::fs::read_to_string(stem.with_extension("json"))
        .with_context(|| format!("reading {}", stem.with_extension("json").display()))?;
    let j = Json::parse(&meta_text).map_err(anyhow::Error::msg)?;
    let shape_entries = j
        .get("shapes")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow!("checkpoint missing shapes"))?;
    let mut shapes = Vec::with_capacity(shape_entries.len());
    for (i, s) in shape_entries.iter().enumerate() {
        let a = s
            .as_arr()
            .ok_or_else(|| anyhow!("checkpoint shapes[{i}]: expected [rows, cols]"))?;
        if a.len() != 2 {
            bail!("checkpoint shapes[{i}]: expected 2 entries, got {}", a.len());
        }
        let rows = a[0]
            .as_usize()
            .ok_or_else(|| anyhow!("checkpoint shapes[{i}]: rows must be a non-negative integer"))?;
        let cols = a[1]
            .as_usize()
            .ok_or_else(|| anyhow!("checkpoint shapes[{i}]: cols must be a non-negative integer"))?;
        if rows == 0 || cols == 0 {
            bail!("checkpoint shapes[{i}]: degenerate shape {rows}x{cols}");
        }
        shapes.push((rows, cols));
    }
    let seed = match j.get("seed") {
        None => 0,
        Some(v) => {
            if let Some(s) = v.as_str() {
                // canonical form: decimal string, lossless for any u64
                s.parse::<u64>()
                    .map_err(|_| anyhow!("checkpoint seed: expected a u64, got {s:?}"))?
            } else if let Some(n) = v.as_f64() {
                // legacy numeric form (pre-string checkpoints; exact only
                // below 2^53)
                n as u64
            } else {
                bail!("checkpoint seed: expected a string or number");
            }
        }
    };
    let meta = CheckpointMeta {
        step: j.get("step").and_then(|v| v.as_usize()).unwrap_or(0),
        eval_loss: j.get("eval_loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
        comp: j.get("comp").and_then(|v| v.as_str()).unwrap_or("").to_string(),
        seed,
        shapes: shapes.clone(),
    };
    let bytes = std::fs::read(stem.with_extension("bin"))?;
    let expect: usize = shapes.iter().map(|(m, n)| m * n * 4).sum();
    if bytes.len() != expect {
        bail!("checkpoint is {} bytes, shapes imply {expect}", bytes.len());
    }
    let mut params = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for (m, n) in &shapes {
        let count = m * n;
        let mut data = Vec::with_capacity(count);
        for i in 0..count {
            data.push(f32::from_le_bytes(
                bytes[off + 4 * i..off + 4 * i + 4].try_into().unwrap(),
            ));
        }
        off += 4 * count;
        params.push(Matrix::from_vec(*m, *n, data));
    }
    Ok((params, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let params = vec![Matrix::randn(4, 6, 1.0, &mut rng), Matrix::randn(3, 1, 1.0, &mut rng)];
        let meta = CheckpointMeta {
            step: 42,
            eval_loss: 3.25,
            comp: "rank:0.15+nat".into(),
            seed: 7,
            shapes: vec![(4, 6), (3, 1)],
        };
        let dir = std::env::temp_dir().join("efmuon_ckpt_test");
        let stem = dir.join("ck");
        save(&stem, &params, &meta).unwrap();
        let (back, meta2) = load(&stem).unwrap();
        assert_eq!(meta2, meta);
        assert_eq!(back.len(), 2);
        for (a, b) in back.iter().zip(&params) {
            assert_eq!(a.data, b.data);
        }
        // atomic save leaves no tmp droppings behind
        assert!(!stem.with_extension("bin.tmp").exists());
        assert!(!stem.with_extension("json.tmp").exists());
    }

    #[test]
    fn detects_truncation() {
        let mut rng = Rng::new(2);
        let params = vec![Matrix::randn(5, 5, 1.0, &mut rng)];
        let meta = CheckpointMeta {
            step: 0,
            eval_loss: 0.0,
            comp: "id".into(),
            seed: 0,
            shapes: vec![(5, 5)],
        };
        let dir = std::env::temp_dir().join("efmuon_ckpt_trunc");
        let stem = dir.join("ck");
        save(&stem, &params, &meta).unwrap();
        // truncate the bin
        let bin = stem.with_extension("bin");
        let bytes = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &bytes[..bytes.len() - 4]).unwrap();
        assert!(load(&stem).is_err());
    }

    #[test]
    fn seed_roundtrips_above_f64_precision() {
        let params = vec![Matrix::from_vec(1, 1, vec![1.0])];
        // 2^63 + 1: corrupted by any f64 round trip
        let seed = (1u64 << 63) + 1;
        let meta = CheckpointMeta {
            step: 3,
            eval_loss: 0.5,
            comp: "id".into(),
            seed,
            shapes: vec![(1, 1)],
        };
        let dir = std::env::temp_dir().join("efmuon_ckpt_seed");
        let stem = dir.join("ck");
        save(&stem, &params, &meta).unwrap();
        let (_, back) = load(&stem).unwrap();
        assert_eq!(back.seed, seed, "seed must round-trip losslessly");
    }

    #[test]
    fn legacy_numeric_seed_still_parses() {
        let dir = std::env::temp_dir().join("efmuon_ckpt_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let stem = dir.join("ck");
        std::fs::write(
            stem.with_extension("json"),
            r#"{"step": 5, "eval_loss": 1.0, "comp": "id", "seed": 99,
                "shapes": [[1, 1]]}"#,
        )
        .unwrap();
        std::fs::write(stem.with_extension("bin"), 1.0f32.to_le_bytes()).unwrap();
        let (_, meta) = load(&stem).unwrap();
        assert_eq!(meta.seed, 99);
        assert_eq!(meta.step, 5);
    }

    #[test]
    fn malformed_metadata_errors_cleanly() {
        let dir = std::env::temp_dir().join("efmuon_ckpt_malformed");
        std::fs::create_dir_all(&dir).unwrap();
        let stem = dir.join("ck");
        std::fs::write(stem.with_extension("bin"), [0u8; 4]).unwrap();
        let cases = [
            // shapes entry not an array: used to panic on as_arr().unwrap()
            (r#"{"shapes": [7]}"#, "expected [rows, cols]"),
            // wrong arity
            (r#"{"shapes": [[4]]}"#, "expected 2 entries"),
            // non-integer dims: used to become silent zero shapes
            (r#"{"shapes": [["x", "y"]]}"#, "non-negative integer"),
            // zero dims
            (r#"{"shapes": [[0, 5]]}"#, "degenerate"),
            // garbage seed
            (r#"{"shapes": [[1, 1]], "seed": "not-a-number"}"#, "seed"),
            (r#"{"shapes": [[1, 1]], "seed": true}"#, "seed"),
            // no shapes at all
            (r#"{"step": 1}"#, "missing shapes"),
        ];
        for (text, needle) in cases {
            std::fs::write(stem.with_extension("json"), text).unwrap();
            let err = load(&stem).expect_err(text).to_string();
            assert!(err.contains(needle), "case {text:?}: {err}");
        }
    }
}
