//! Checkpointing: persist/restore model parameters + run metadata so long
//! pretraining runs survive restarts (and so trained models can be handed
//! to downstream tools). Format: `<stem>.bin` (f32 LE, layer order) +
//! `<stem>.json` (metadata incl. shape table for validation).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::matrix::{Layers, Matrix};
use crate::util::json::{Json, JsonObj};

/// Metadata stored alongside the weights.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckpointMeta {
    pub step: usize,
    pub eval_loss: f64,
    pub comp: String,
    pub seed: u64,
    pub shapes: Vec<(usize, usize)>,
}

/// Write `<stem>.bin` + `<stem>.json`.
pub fn save(stem: impl AsRef<Path>, params: &Layers, meta: &CheckpointMeta) -> Result<()> {
    let stem = stem.as_ref();
    if let Some(parent) = stem.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut bytes = Vec::with_capacity(params.iter().map(|p| p.numel() * 4).sum());
    for p in params {
        for v in &p.data {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
    }
    std::fs::write(stem.with_extension("bin"), &bytes)?;
    let shapes: Vec<Json> = params
        .iter()
        .map(|p| Json::Arr(vec![Json::Num(p.rows as f64), Json::Num(p.cols as f64)]))
        .collect();
    let j = JsonObj::new()
        .put("step", meta.step)
        .put("eval_loss", meta.eval_loss)
        .put("comp", meta.comp.as_str())
        .put("seed", meta.seed)
        .put("shapes", Json::Arr(shapes))
        .build();
    std::fs::write(stem.with_extension("json"), j.to_string())?;
    Ok(())
}

/// Read a checkpoint; validates the byte count against the shape table.
pub fn load(stem: impl AsRef<Path>) -> Result<(Layers, CheckpointMeta)> {
    let stem = stem.as_ref();
    let meta_text = std::fs::read_to_string(stem.with_extension("json"))
        .with_context(|| format!("reading {}", stem.with_extension("json").display()))?;
    let j = Json::parse(&meta_text).map_err(anyhow::Error::msg)?;
    let shapes: Vec<(usize, usize)> = j
        .get("shapes")
        .and_then(|s| s.as_arr())
        .ok_or_else(|| anyhow::anyhow!("checkpoint missing shapes"))?
        .iter()
        .map(|s| {
            let a = s.as_arr().unwrap();
            (a[0].as_usize().unwrap_or(0), a[1].as_usize().unwrap_or(0))
        })
        .collect();
    let meta = CheckpointMeta {
        step: j.get("step").and_then(|v| v.as_usize()).unwrap_or(0),
        eval_loss: j.get("eval_loss").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
        comp: j.get("comp").and_then(|v| v.as_str()).unwrap_or("").to_string(),
        seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        shapes: shapes.clone(),
    };
    let bytes = std::fs::read(stem.with_extension("bin"))?;
    let expect: usize = shapes.iter().map(|(m, n)| m * n * 4).sum();
    if bytes.len() != expect {
        bail!("checkpoint is {} bytes, shapes imply {expect}", bytes.len());
    }
    let mut params = Vec::with_capacity(shapes.len());
    let mut off = 0;
    for (m, n) in &shapes {
        let count = m * n;
        let mut data = Vec::with_capacity(count);
        for i in 0..count {
            data.push(f32::from_le_bytes(
                bytes[off + 4 * i..off + 4 * i + 4].try_into().unwrap(),
            ));
        }
        off += 4 * count;
        params.push(Matrix::from_vec(*m, *n, data));
    }
    Ok((params, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let params = vec![Matrix::randn(4, 6, 1.0, &mut rng), Matrix::randn(3, 1, 1.0, &mut rng)];
        let meta = CheckpointMeta {
            step: 42,
            eval_loss: 3.25,
            comp: "rank:0.15+nat".into(),
            seed: 7,
            shapes: vec![(4, 6), (3, 1)],
        };
        let dir = std::env::temp_dir().join("efmuon_ckpt_test");
        let stem = dir.join("ck");
        save(&stem, &params, &meta).unwrap();
        let (back, meta2) = load(&stem).unwrap();
        assert_eq!(meta2, meta);
        assert_eq!(back.len(), 2);
        for (a, b) in back.iter().zip(&params) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn detects_truncation() {
        let mut rng = Rng::new(2);
        let params = vec![Matrix::randn(5, 5, 1.0, &mut rng)];
        let meta = CheckpointMeta {
            step: 0,
            eval_loss: 0.0,
            comp: "id".into(),
            seed: 0,
            shapes: vec![(5, 5)],
        };
        let dir = std::env::temp_dir().join("efmuon_ckpt_trunc");
        let stem = dir.join("ck");
        save(&stem, &params, &meta).unwrap();
        // truncate the bin
        let bin = stem.with_extension("bin");
        let bytes = std::fs::read(&bin).unwrap();
        std::fs::write(&bin, &bytes[..bytes.len() - 4]).unwrap();
        assert!(load(&stem).is_err());
    }
}
