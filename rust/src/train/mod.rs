//! End-to-end training orchestration: wire a [`TrainConfig`] into the
//! distributed coordinator + PJRT grad service, run the schedule, evaluate,
//! and log. This is the module behind `efmuon train` and the experiment
//! drivers in [`crate::exp`].

pub mod checkpoint;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::dist::cluster::{Cluster, ClusterCfg};
use crate::dist::coordinator::{Coordinator, CoordinatorCfg};
use crate::dist::service::GradService;
use crate::dist::{RoundMode, TransportMode};
use crate::metrics::JsonlWriter;
use crate::model::{Group, Manifest};
use crate::opt::{LayerGeometry, Schedule};
use crate::util::json::JsonObj;

/// One evaluation point on the loss curve.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub tokens_processed: u64,
    pub w2s_bytes_per_worker: u64,
    pub eval_loss: f32,
}

/// Result of a full training run (the raw material of Figures 1–2).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub config_comp: String,
    pub steps: usize,
    pub final_eval_loss: f32,
    pub curve: Vec<EvalPoint>,
    pub train_losses: Vec<f32>,
    pub total_w2s_bytes_per_worker: u64,
    pub total_s2w_bytes: u64,
    pub model_bytes: usize,
    pub tokens_per_step: usize,
    pub wall_seconds: f64,
}

impl TrainReport {
    /// Steps needed to first reach `target` eval loss (None = never).
    pub fn steps_to_loss(&self, target: f32) -> Option<usize> {
        self.curve.iter().find(|p| p.eval_loss <= target).map(|p| p.step)
    }

    /// Tokens needed to first reach `target` eval loss.
    pub fn tokens_to_loss(&self, target: f32) -> Option<u64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss <= target)
            .map(|p| p.tokens_processed)
    }

    /// Per-worker w2s bytes (normalized by model size) to reach `target` —
    /// the Figure 1-right / Figure 2 y-axis.
    pub fn relative_bytes_to_loss(&self, target: f32) -> Option<f64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss <= target)
            .map(|p| p.w2s_bytes_per_worker as f64 / self.model_bytes as f64)
    }
}

/// Per-layer geometry with the config's group multipliers applied.
pub fn geometry_for(manifest: &Manifest, cfg: &TrainConfig) -> Vec<LayerGeometry> {
    manifest
        .layers
        .iter()
        .map(|l| {
            let mut g = l.group.geometry();
            match l.group {
                Group::Embed => g.radius_mult *= cfg.embed_mult,
                Group::Vector => g.radius_mult *= cfg.vector_mult / 0.1, // base already 0.1
                Group::Hidden => {}
            }
            g
        })
        .collect()
}

/// Driver-agnostic telemetry of one round (what the shared loop consumes).
struct DriveRound {
    /// Whether this call absorbed a round (async pipelines absorb nothing
    /// for the first `lookahead` calls).
    absorbed: bool,
    train_loss: f32,
    radius: f64,
}

/// The deployment surface the shared training loop drives: one round at a
/// time, a drain before the final eval, an eval, and the byte/round meters
/// the eval points record. Implemented by the single [`Coordinator`] and
/// the sharded [`Cluster`], so there is exactly one loop to keep correct —
/// the two previous near-duplicate loops could silently drift.
trait Driver {
    fn round(&mut self) -> Result<DriveRound>;
    /// Land every in-flight round (no-op in sync mode); returns the drained
    /// rounds' train losses in absorption order.
    fn drain_losses(&mut self) -> Result<Vec<f32>>;
    fn eval(&mut self) -> Result<f32>;
    /// Rounds fully absorbed so far (tokens are paired with this, so both
    /// token and byte meters count absorbed work).
    fn rounds_absorbed(&self) -> u64;
    /// w2s bytes one (logical full-model) worker has sent.
    fn w2s(&self) -> u64;
    /// s2w broadcast bytes.
    fn s2w(&self) -> u64;
    /// Driver-specific keys appended to each eval log record.
    fn annotate(&self, o: JsonObj) -> JsonObj;
}

impl Driver for Coordinator {
    fn round(&mut self) -> Result<DriveRound> {
        let s = Coordinator::round(self)?;
        Ok(DriveRound {
            absorbed: s.absorbed_step.is_some(),
            train_loss: s.train_loss,
            radius: s.radius,
        })
    }

    fn drain_losses(&mut self) -> Result<Vec<f32>> {
        Ok(Coordinator::drain(self)?.into_iter().map(|s| s.train_loss).collect())
    }

    fn eval(&mut self) -> Result<f32> {
        Coordinator::eval(self)
    }

    fn rounds_absorbed(&self) -> u64 {
        self.meter().rounds_absorbed()
    }

    fn w2s(&self) -> u64 {
        self.meter().w2s()
    }

    fn s2w(&self) -> u64 {
        self.meter().s2w()
    }

    fn annotate(&self, o: JsonObj) -> JsonObj {
        o
    }
}

impl Driver for Cluster {
    fn round(&mut self) -> Result<DriveRound> {
        let s = Cluster::round(self)?;
        Ok(DriveRound {
            absorbed: s.absorbed_step.is_some(),
            train_loss: s.train_loss,
            radius: s.radius,
        })
    }

    fn drain_losses(&mut self) -> Result<Vec<f32>> {
        Ok(Cluster::drain(self)?.into_iter().map(|s| s.train_loss).collect())
    }

    fn eval(&mut self) -> Result<f32> {
        Cluster::eval(self)
    }

    fn rounds_absorbed(&self) -> u64 {
        self.meter().rounds_absorbed()
    }

    fn w2s(&self) -> u64 {
        self.meter().w2s()
    }

    fn s2w(&self) -> u64 {
        self.meter().s2w()
    }

    fn annotate(&self, o: JsonObj) -> JsonObj {
        let meter = self.meter();
        o.put("shards", self.shards())
            .put("s2w_bytes", meter.s2w())
            .put("meter", meter.to_json())
    }
}

/// Run one full distributed training job per the config. `shards = 1`
/// drives the single [`Coordinator`] (the exact deployment of every prior
/// PR); `shards > 1` partitions the model's layers across a
/// [`Cluster`] of concurrent shard coordinators. Both run the *same*
/// [`Driver`] loop — only the deployment construction differs.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    if cfg.shards == 0 {
        // reject rather than silently reinterpret as 1 (the same hardening
        // contract as RoundMode::parse)
        return Err(anyhow::anyhow!("shards must be >= 1 (got 0); use --shards 1 for the single-leader deployment"));
    }
    let manifest = Manifest::load(&cfg.artifacts).map_err(anyhow::Error::msg)?;
    let x0 = manifest.load_init_params().map_err(anyhow::Error::msg)?;
    let geometry = geometry_for(&manifest, cfg);
    // the logical data workers are shared across shards (shard s's worker j
    // is data worker j), so tokens per round are shard-count invariant
    let tokens_per_step = manifest.batch * manifest.seq_len * cfg.workers;
    let model_bytes = manifest.model_bytes();

    let svc = GradService::spawn_pjrt(
        cfg.artifacts.clone(),
        cfg.workers,
        cfg.corpus_tokens,
        cfg.eval_batches,
        cfg.seed,
    )?;
    let schedule = Schedule::warmup_cosine(cfg.lr, cfg.warmup, cfg.steps, cfg.min_lr_frac);
    let transport = if cfg.full_codec {
        TransportMode::Encoded
    } else {
        TransportMode::Counted
    };
    let round_mode = RoundMode::parse(&cfg.round_mode).map_err(anyhow::Error::msg)?;

    if cfg.shards > 1 {
        let mut cluster = Cluster::spawn(
            x0,
            geometry,
            svc.handle(),
            ClusterCfg {
                shards: cfg.shards,
                workers_per_shard: cfg.workers,
                worker_comp: cfg.worker_comp.clone(),
                server_comp: cfg.server_comp.clone(),
                beta: cfg.beta,
                schedule,
                transport,
                round_mode,
                seed: cfg.seed,
                use_ns_artifact: cfg.use_ns_artifact,
            },
        )?;
        run_driver(cfg, &mut cluster, tokens_per_step, model_bytes)
    } else {
        let mut coord = Coordinator::spawn(
            x0,
            geometry,
            svc.handle(),
            CoordinatorCfg {
                n_workers: cfg.workers,
                worker_comp: cfg.worker_comp.clone(),
                server_comp: cfg.server_comp.clone(),
                beta: cfg.beta,
                schedule,
                transport,
                round_mode,
                seed: cfg.seed,
                use_ns_artifact: cfg.use_ns_artifact,
            },
        )?;
        run_driver(cfg, &mut coord, tokens_per_step, model_bytes)
    }
}

/// The one training loop, shared by both topologies: round →
/// absorbed-loss → drain at the last step only → eval → log. Mid-run evals
/// never drain, so the observation frequency (`eval_every`) can never
/// perturb the optimization trajectory; the final eval drains every
/// pipeline first, so the reported loss reflects fully-absorbed rounds.
fn run_driver(
    cfg: &TrainConfig,
    drv: &mut dyn Driver,
    tokens_per_step: usize,
    model_bytes: usize,
) -> Result<TrainReport> {
    let mut log = match &cfg.log_path {
        Some(p) => Some(JsonlWriter::create(p)?),
        None => None,
    };
    let timer = crate::util::timer::Timer::start();
    let mut curve = Vec::new();
    let mut train_losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let stats = drv.round()?;
        // async modes: the first `lookahead` calls absorb no round yet, so
        // there is no train loss to record for them
        if stats.absorbed {
            train_losses.push(stats.train_loss);
        }
        let last = step + 1 == cfg.steps;
        if last {
            train_losses.extend(drv.drain_losses()?);
        }
        let do_eval = step % cfg.eval_every.max(1) == 0 || last;
        if do_eval {
            let eval_loss = drv.eval()?;
            // pair tokens with the byte meter: both count *absorbed* rounds
            // (== step+1 in sync mode; in async modes eval_loss runs at most
            // `lookahead` issued-but-unabsorbed LMO steps ahead of them)
            let point = EvalPoint {
                step,
                tokens_processed: (tokens_per_step as u64) * drv.rounds_absorbed(),
                w2s_bytes_per_worker: drv.w2s(),
                eval_loss,
            };
            if let Some(log) = log.as_mut() {
                let mut o = JsonObj::new()
                    .put("step", step)
                    .put("eval_loss", eval_loss)
                    .put("tokens", point.tokens_processed)
                    .put("w2s_bytes", point.w2s_bytes_per_worker)
                    .put("radius", stats.radius);
                // async modes: no train loss has landed yet in the first
                // `lookahead` rounds — omit the key rather than emit NaN
                // (which would not be valid JSON)
                if let Some(l) = train_losses.last().copied() {
                    o = o.put("train_loss", l);
                }
                o = drv.annotate(o);
                log.write(&o)?;
                log.flush()?;
            }
            curve.push(point);
        }
    }

    Ok(TrainReport {
        config_comp: cfg.worker_comp.clone(),
        steps: cfg.steps,
        final_eval_loss: curve.last().map(|p| p.eval_loss).unwrap_or(f32::NAN),
        curve,
        train_losses,
        total_w2s_bytes_per_worker: drv.w2s(),
        total_s2w_bytes: drv.s2w(),
        model_bytes,
        tokens_per_step,
        wall_seconds: timer.seconds(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shards_is_rejected_before_anything_loads() {
        let cfg = TrainConfig { shards: 0, ..TrainConfig::default() };
        let err = train(&cfg).expect_err("shards=0 must be rejected");
        assert!(format!("{err:#}").contains("shards must be >= 1"), "{err:#}");
    }
}
