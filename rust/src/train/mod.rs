//! End-to-end training orchestration: validate a config into a typed
//! [`RunSpec`], construct a deployment behind the [`Driver`] trait, run the
//! schedule, evaluate, and log. This is the module behind `efmuon train`
//! and the experiment drivers in [`crate::exp`].
//!
//! Configuration flows one way: `TrainConfig` (strings) →
//! [`TrainConfig::validate`] → [`RunSpec`] (typed, validated) →
//! [`spawn_driver`] → a [`Driver`]. No spec string is ever parsed past the
//! first arrow.

pub mod checkpoint;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::dist::cluster::Cluster;
use crate::dist::coordinator::Coordinator;
use crate::dist::net::{NetCfg, NetHub};
use crate::dist::service::{GradHandle, GradService};
use crate::funcs::Objective;
use crate::linalg::matrix::Layers;
use crate::model::Manifest;
use crate::opt::ef21::Ef21MuonSeq;
use crate::opt::LayerGeometry;
use crate::spec::RunSpec;
use crate::trace::{TraceRing, Tracer};
use crate::util::json::JsonObj;

use std::sync::Arc;

/// One evaluation point on the loss curve.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub tokens_processed: u64,
    pub w2s_bytes_per_worker: u64,
    pub eval_loss: f32,
}

/// Result of a full training run (the raw material of Figures 1–2).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub config_comp: String,
    pub steps: usize,
    pub final_eval_loss: f32,
    pub curve: Vec<EvalPoint>,
    pub train_losses: Vec<f32>,
    pub total_w2s_bytes_per_worker: u64,
    pub total_s2w_bytes: u64,
    pub model_bytes: usize,
    pub tokens_per_step: usize,
    pub wall_seconds: f64,
}

impl TrainReport {
    /// Steps needed to first reach `target` eval loss (None = never).
    pub fn steps_to_loss(&self, target: f32) -> Option<usize> {
        self.curve.iter().find(|p| p.eval_loss <= target).map(|p| p.step)
    }

    /// Tokens needed to first reach `target` eval loss.
    pub fn tokens_to_loss(&self, target: f32) -> Option<u64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss <= target)
            .map(|p| p.tokens_processed)
    }

    /// Per-worker w2s bytes (normalized by model size) to reach `target` —
    /// the Figure 1-right / Figure 2 y-axis.
    pub fn relative_bytes_to_loss(&self, target: f32) -> Option<f64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss <= target)
            .map(|p| p.w2s_bytes_per_worker as f64 / self.model_bytes as f64)
    }
}

/// Driver-agnostic telemetry of one round (mirrors the coordinator's
/// `RoundStats` / the cluster rollup, minus topology-specific detail).
#[derive(Debug, Clone)]
pub struct DriveRound {
    /// The round whose broadcast this call issued.
    pub step: usize,
    /// The round whose uplinks this call absorbed, if any (async pipelines
    /// absorb nothing for the first `lookahead` calls).
    pub absorbed_step: Option<usize>,
    /// Train loss of the absorbed round (NaN while the pipeline fills).
    pub train_loss: f32,
    /// LMO radius of the issued round.
    pub radius: f64,
    /// w2s bytes one (logical full-model) worker sent in the absorbed round.
    pub w2s_bytes_per_worker: usize,
    /// s2w broadcast bytes of the issued round.
    pub s2w_bytes: usize,
}

/// The deployment surface the shared training loop drives: one round at a
/// time, a drain before the final eval, an eval, and the byte/round meters
/// the eval points record. Implemented by the single [`Coordinator`], the
/// sharded [`Cluster`], and the sequential reference [`SeqDriver`] — so
/// there is exactly one loop to keep correct, and every entry point
/// (`train`, the `exp` sweeps, benches, scenario tests) constructs its
/// deployment through [`spawn_driver`] instead of hand-wiring one.
pub trait Driver {
    fn round(&mut self) -> Result<DriveRound>;
    /// Land every in-flight round (no-op in sync mode); returns the drained
    /// rounds in absorption order (their broadcasts were already metered
    /// when issued, so `s2w_bytes` is 0 on these entries).
    fn drain(&mut self) -> Result<Vec<DriveRound>>;
    fn eval(&mut self) -> Result<f32>;
    /// Current full-model parameters.
    fn params(&mut self) -> Result<Layers>;
    /// Rounds fully absorbed so far (tokens are paired with this, so both
    /// token and byte meters count absorbed work).
    fn rounds_absorbed(&self) -> u64;
    /// w2s bytes one (logical full-model) worker has sent.
    fn w2s(&self) -> u64;
    /// s2w broadcast bytes.
    fn s2w(&self) -> u64;
    /// Driver-specific keys appended to each eval log record.
    fn annotate(&self, o: JsonObj) -> JsonObj;
}

impl From<crate::dist::coordinator::RoundStats> for DriveRound {
    fn from(s: crate::dist::coordinator::RoundStats) -> DriveRound {
        DriveRound {
            step: s.step,
            absorbed_step: s.absorbed_step,
            train_loss: s.train_loss,
            radius: s.radius,
            w2s_bytes_per_worker: s.w2s_bytes_per_worker,
            s2w_bytes: s.s2w_bytes,
        }
    }
}

impl From<crate::dist::cluster::ClusterRoundStats> for DriveRound {
    fn from(s: crate::dist::cluster::ClusterRoundStats) -> DriveRound {
        DriveRound {
            step: s.step,
            absorbed_step: s.absorbed_step,
            train_loss: s.train_loss,
            radius: s.radius,
            w2s_bytes_per_worker: s.w2s_bytes_per_worker,
            s2w_bytes: s.s2w_bytes,
        }
    }
}

impl Driver for Coordinator {
    fn round(&mut self) -> Result<DriveRound> {
        Ok(Coordinator::round(self)?.into())
    }

    fn drain(&mut self) -> Result<Vec<DriveRound>> {
        Ok(Coordinator::drain(self)?.into_iter().map(Into::into).collect())
    }

    fn eval(&mut self) -> Result<f32> {
        Coordinator::eval(self)
    }

    fn params(&mut self) -> Result<Layers> {
        Ok(Coordinator::params(self).clone())
    }

    fn rounds_absorbed(&self) -> u64 {
        self.meter().rounds_absorbed()
    }

    fn w2s(&self) -> u64 {
        self.meter().w2s()
    }

    fn s2w(&self) -> u64 {
        self.meter().s2w()
    }

    fn annotate(&self, o: JsonObj) -> JsonObj {
        o
    }
}

impl Driver for Cluster {
    fn round(&mut self) -> Result<DriveRound> {
        Ok(Cluster::round(self)?.into())
    }

    fn drain(&mut self) -> Result<Vec<DriveRound>> {
        Ok(Cluster::drain(self)?.into_iter().map(Into::into).collect())
    }

    fn eval(&mut self) -> Result<f32> {
        Cluster::eval(self)
    }

    fn params(&mut self) -> Result<Layers> {
        Cluster::params(self)
    }

    fn rounds_absorbed(&self) -> u64 {
        self.meter().rounds_absorbed()
    }

    fn w2s(&self) -> u64 {
        self.meter().w2s()
    }

    fn s2w(&self) -> u64 {
        self.meter().s2w()
    }

    fn annotate(&self, o: JsonObj) -> JsonObj {
        let meter = self.meter();
        o.put("shards", self.shards())
            .put("s2w_bytes", meter.s2w())
            .put("meter", meter.to_json())
    }
}

/// The sequential single-process reference deployment ([`Ef21MuonSeq`])
/// behind the same [`Driver`] surface, so offline sweeps (e.g.
/// `exp::s2w_savings`) and tests drive Algorithm 3 verbatim through the
/// exact interface the threaded topologies use.
pub struct SeqDriver {
    opt: Ef21MuonSeq,
    obj: Box<dyn Objective>,
}

impl SeqDriver {
    pub fn new(opt: Ef21MuonSeq, obj: Box<dyn Objective>) -> SeqDriver {
        SeqDriver { opt, obj }
    }

    /// The wrapped sequential optimizer (tests inspect protocol state).
    pub fn inner(&self) -> &Ef21MuonSeq {
        &self.opt
    }

    /// Full-precision loss at the current parameters. [`Driver::eval`]
    /// narrows to f32 for trait uniformity; offline sweeps that always
    /// reported f64 (e.g. `exp::s2w_savings`) read this instead.
    pub fn loss_f64(&self) -> f64 {
        self.obj.loss(self.opt.params())
    }
}

impl Driver for SeqDriver {
    fn round(&mut self) -> Result<DriveRound> {
        let s = self.opt.step(self.obj.as_ref());
        Ok(DriveRound {
            step: s.step,
            absorbed_step: Some(s.step),
            train_loss: s.loss as f32,
            radius: s.radius,
            w2s_bytes_per_worker: s.w2s_bytes,
            s2w_bytes: s.s2w_bytes,
        })
    }

    fn drain(&mut self) -> Result<Vec<DriveRound>> {
        Ok(Vec::new()) // fully synchronous: nothing is ever in flight
    }

    fn eval(&mut self) -> Result<f32> {
        Ok(self.obj.loss(self.opt.params()) as f32)
    }

    fn params(&mut self) -> Result<Layers> {
        Ok(self.opt.params().clone())
    }

    fn rounds_absorbed(&self) -> u64 {
        self.opt.step as u64
    }

    fn w2s(&self) -> u64 {
        self.opt.total_w2s_bytes
    }

    fn s2w(&self) -> u64 {
        self.opt.total_s2w_bytes
    }

    fn annotate(&self, o: JsonObj) -> JsonObj {
        o.put("driver", "seq")
    }
}

/// Construct the deployment a [`RunSpec`] describes over an already-running
/// gradient service: the single [`Coordinator`] for `shards = 1` (the exact
/// deployment of every prior PR) or a sharded [`Cluster`] — both behind the
/// [`Driver`] trait, so callers never hand-assemble optimizer wiring.
pub fn spawn_driver(
    spec: &RunSpec,
    x0: Layers,
    geometry: Vec<LayerGeometry>,
    handle: GradHandle,
) -> Result<Box<dyn Driver>> {
    spawn_driver_at(spec, x0, geometry, handle, 0)
}

/// [`spawn_driver`], but with the round counter — and thus the LR-schedule
/// position — starting at `start_step`: the resume path. `start_step` rides
/// on the driver cfg rather than the spec because it is run *state*, not
/// run shape; the spec of a resumed run stays byte-identical to the
/// original's.
pub fn spawn_driver_at(
    spec: &RunSpec,
    x0: Layers,
    geometry: Vec<LayerGeometry>,
    handle: GradHandle,
    start_step: usize,
) -> Result<Box<dyn Driver>> {
    spawn_driver_traced(spec, x0, geometry, handle, start_step, Tracer::Noop)
}

/// [`spawn_driver_at`] with a round-phase [`Tracer`] installed on the
/// deployment cfg. Like `start_step`, the tracer rides on the cfg rather
/// than the spec: the spec carries only the trace *path*, and the live
/// ring handle is run state, constructed by whoever will drain it
/// ([`train_spec`], the hotpath bench, the scenario harness).
pub fn spawn_driver_traced(
    spec: &RunSpec,
    x0: Layers,
    geometry: Vec<LayerGeometry>,
    handle: GradHandle,
    start_step: usize,
    tracer: Tracer,
) -> Result<Box<dyn Driver>> {
    // RunSpec fields are public, so a caller can bypass RunBuilder; keep
    // the old "reject rather than silently reinterpret as 1" contract
    if spec.shards == 0 {
        return Err(anyhow::anyhow!(
            "shards: must be >= 1 (got 0); build the spec through RunBuilder"
        ));
    }
    if spec.shards > 1 {
        let mut cfg = spec.cluster_cfg();
        cfg.start_step = start_step;
        cfg.tracer = tracer;
        Ok(Box::new(Cluster::spawn(x0, geometry, handle, cfg)?))
    } else if let Some(addr) = spec.link.tcp_addr() {
        // socket deployment (`--transport tcp:ADDR` / `efmuon serve`): bind
        // first so workers can start dialing, then arm the hub with this
        // run's protocol parameters and wait for `workers` of them
        let mut cfg = spec.coordinator_cfg();
        cfg.start_step = start_step;
        cfg.tracer = tracer;
        let hub = NetHub::bind(NetCfg { listen: addr.to_string(), ..NetCfg::default() })?;
        match Coordinator::spawn_net(x0, geometry, handle, cfg, hub.clone()) {
            Ok(c) => Ok(Box::new(c)),
            Err(e) => {
                // spawn_net arms but could not assemble the deployment; the
                // accept thread holds an Arc and must be shut down here
                hub.close();
                Err(e)
            }
        }
    } else {
        let mut cfg = spec.coordinator_cfg();
        cfg.start_step = start_step;
        cfg.tracer = tracer;
        Ok(Box::new(Coordinator::spawn(x0, geometry, handle, cfg)?))
    }
}

/// The sequential reference deployment of a [`RunSpec`] over a synthetic
/// objective (offline sweeps; no artifacts, no threads).
pub fn spawn_seq_driver(
    spec: &RunSpec,
    obj: Box<dyn Objective>,
    geometry: Vec<LayerGeometry>,
) -> Result<SeqDriver> {
    let opt = Ef21MuonSeq::new(
        obj.as_ref(),
        geometry,
        spec.worker_comp,
        spec.server_comp,
        spec.beta,
        spec.schedule(),
        false,
        spec.seed,
    )
    .map_err(anyhow::Error::msg)?;
    Ok(SeqDriver::new(opt, obj))
}

/// Run one full distributed training job per the (string-facade) config:
/// validate into a [`RunSpec`] — all errors surface here, field-named,
/// before anything loads — then run it.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    let spec = cfg.validate()?;
    train_spec(&spec)
}

/// Run one full distributed training job from a validated [`RunSpec`].
/// `shards = 1` drives the single [`Coordinator`]; `shards > 1` partitions
/// the model's layers across a [`Cluster`] of concurrent shard
/// coordinators. Both run the *same* [`Driver`] loop — only the deployment
/// construction differs (and that lives in [`spawn_driver`]).
pub fn train_spec(spec: &RunSpec) -> Result<TrainReport> {
    let manifest = Manifest::load(&spec.artifacts).map_err(anyhow::Error::msg)?;
    let mut x0 = manifest.load_init_params().map_err(anyhow::Error::msg)?;
    let geometry = spec.geom.for_groups(manifest.layers.iter().map(|l| l.group));
    // the logical data workers are shared across shards (shard s's worker j
    // is data worker j), so tokens per round are shard-count invariant
    let tokens_per_step = manifest.batch * manifest.seq_len * spec.workers;
    let model_bytes = manifest.model_bytes();

    let mut start_step = 0usize;
    if spec.resume {
        let dir = spec
            .checkpoint_dir
            .as_deref()
            .ok_or_else(|| anyhow::anyhow!("resume: requires checkpoint_dir"))?;
        let stem = std::path::Path::new(dir).join(CHECKPOINT_STEM);
        if stem.with_extension("json").exists() {
            let (params, meta) = checkpoint::load(&stem)?;
            let want: Vec<(usize, usize)> = x0.iter().map(|p| (p.rows, p.cols)).collect();
            if meta.shapes != want {
                return Err(anyhow::anyhow!(
                    "resume: checkpoint shapes {:?} do not match the manifest model {:?}",
                    meta.shapes,
                    want
                ));
            }
            x0 = params;
            start_step = meta.step;
        } else {
            // a missing checkpoint on --resume is the normal first launch of
            // a restartable job, not an error — announce and start fresh
            eprintln!("resume: no checkpoint at {}, starting fresh", stem.display());
        }
    }

    let svc = GradService::spawn_pjrt(
        spec.artifacts.clone(),
        spec.workers,
        spec.corpus_tokens,
        spec.eval_batches,
        spec.seed,
    )?;
    let (tracer, ring) = match &spec.trace_path {
        Some(_) => {
            let (t, r) = Tracer::ring(TRACE_RING_CAP);
            (t, Some(r))
        }
        None => (Tracer::Noop, None),
    };
    let mut drv = spawn_driver_traced(spec, x0, geometry, svc.handle(), start_step, tracer)?;
    run_driver(spec, drv.as_mut(), tokens_per_step, model_bytes, start_step, ring)
}

/// Trace-ring capacity for `--trace` runs: a generous per-round event
/// budget (every phase of every worker of every shard fits many times
/// over), drained once per round so overflow only occurs if a single round
/// stamps more than this.
pub const TRACE_RING_CAP: usize = 65_536;

/// Stem (within `checkpoint_dir`) every checkpoint is saved under — and
/// the one `--resume` looks for.
pub const CHECKPOINT_STEM: &str = "ck";

/// The one training loop, shared by every topology: round →
/// absorbed-loss → drain at the last step only → eval → log. Mid-run evals
/// never drain, so the observation frequency (`eval_every`) can never
/// perturb the optimization trajectory; the final eval drains every
/// pipeline first, so the reported loss reflects fully-absorbed rounds.
///
/// Checkpoints (`checkpoint_every > 0`) *do* drain before saving — the
/// saved parameters must reflect every issued round or a resume would
/// silently drop in-flight work. In sync mode that drain is a no-op, so
/// checkpointing never perturbs the trajectory; in async modes each
/// checkpoint flushes the pipeline (momentarily lock-step), which changes
/// wall-clock overlap but not the absorbed-round algebra.
fn run_driver(
    spec: &RunSpec,
    drv: &mut dyn Driver,
    tokens_per_step: usize,
    model_bytes: usize,
    start_step: usize,
    ring: Option<Arc<TraceRing>>,
) -> Result<TrainReport> {
    let mut log = match &spec.log_path {
        Some(p) => Some(crate::metrics::JsonlWriter::create(p)?),
        None => None,
    };
    // trace drain sink: one JSONL row per stamped event, drained each round
    // so the bounded ring never wraps on a healthy run
    let mut trace_log = match (&spec.trace_path, &ring) {
        (Some(p), Some(_)) => Some(crate::metrics::JsonlWriter::create(p)?),
        _ => None,
    };
    let ckpt_stem = match (spec.checkpoint_every > 0, &spec.checkpoint_dir) {
        (true, Some(dir)) => Some(std::path::Path::new(dir).join(CHECKPOINT_STEM)),
        (true, None) => {
            // RunBuilder rejects this; guard the public-field bypass
            return Err(anyhow::anyhow!("checkpoint_every: requires checkpoint_dir"));
        }
        _ => None,
    };
    let timer = crate::util::timer::Timer::start();
    let mut curve = Vec::new();
    let mut train_losses = Vec::with_capacity(spec.steps.saturating_sub(start_step));

    for step in start_step..spec.steps {
        let stats = drv.round()?;
        if let (Some(tl), Some(r)) = (trace_log.as_mut(), ring.as_ref()) {
            for ev in r.drain() {
                tl.write(&ev.to_obj())?;
            }
        }
        // async modes: the first `lookahead` calls absorb no round yet, so
        // there is no train loss to record for them
        if stats.absorbed_step.is_some() {
            train_losses.push(stats.train_loss);
        }
        let last = step + 1 == spec.steps;
        let do_ckpt =
            ckpt_stem.is_some() && ((step + 1) % spec.checkpoint_every.max(1) == 0 || last);
        if last || do_ckpt {
            train_losses.extend(
                drv.drain()?
                    .into_iter()
                    .filter(|d| d.absorbed_step.is_some())
                    .map(|d| d.train_loss),
            );
        }
        // eval_every >= 1 is a RunBuilder invariant, but RunSpec fields are
        // public — guard rather than panic on a hand-built spec
        let do_eval = step % spec.eval_every.max(1) == 0 || last;
        if do_eval {
            let eval_loss = drv.eval()?;
            // pair tokens with the byte meter: both count *absorbed* rounds
            // (== step+1 in sync mode; in async modes eval_loss runs at most
            // `lookahead` issued-but-unabsorbed LMO steps ahead of them)
            let point = EvalPoint {
                step,
                tokens_processed: (tokens_per_step as u64) * drv.rounds_absorbed(),
                w2s_bytes_per_worker: drv.w2s(),
                eval_loss,
            };
            if let Some(log) = log.as_mut() {
                let mut o = JsonObj::new()
                    .put("step", step)
                    .put("eval_loss", eval_loss)
                    .put("tokens", point.tokens_processed)
                    .put("w2s_bytes", point.w2s_bytes_per_worker)
                    .put("radius", stats.radius);
                // async modes: no train loss has landed yet in the first
                // `lookahead` rounds — omit the key rather than emit NaN
                // (which would not be valid JSON)
                if let Some(l) = train_losses.last().copied() {
                    o = o.put("train_loss", l);
                }
                o = drv.annotate(o);
                log.write(&o)?;
                log.flush()?;
            }
            curve.push(point);
        }
        if do_ckpt {
            let stem = ckpt_stem.as_ref().expect("do_ckpt implies a stem");
            // every issued round was just drained, so step+1 rounds are
            // fully absorbed into these parameters
            let params = drv.params()?;
            let meta = checkpoint::CheckpointMeta {
                step: step + 1,
                eval_loss: curve.last().map(|p| p.eval_loss as f64).unwrap_or(f64::NAN),
                comp: spec.worker_comp.spec(),
                seed: spec.seed,
                shapes: params.iter().map(|p| (p.rows, p.cols)).collect(),
            };
            checkpoint::save(stem, &params, &meta)?;
        }
    }

    // final trace drain: late-landing events stamped during the last
    // drain/eval (pipelined shards, late folds) still reach the file
    if let (Some(tl), Some(r)) = (trace_log.as_mut(), ring.as_ref()) {
        for ev in r.drain() {
            tl.write(&ev.to_obj())?;
        }
        tl.flush()?;
    }

    // resuming a checkpoint taken at (or past) the final step: the loop
    // body never ran, so evaluate the restored parameters once rather than
    // report an empty curve
    if curve.is_empty() {
        curve.push(EvalPoint {
            step: start_step,
            tokens_processed: (tokens_per_step as u64) * drv.rounds_absorbed(),
            w2s_bytes_per_worker: drv.w2s(),
            eval_loss: drv.eval()?,
        });
    }

    Ok(TrainReport {
        config_comp: spec.worker_comp.spec(),
        steps: spec.steps,
        final_eval_loss: curve.last().map(|p| p.eval_loss).unwrap_or(f32::NAN),
        curve,
        train_losses,
        total_w2s_bytes_per_worker: drv.w2s(),
        total_s2w_bytes: drv.s2w(),
        model_bytes,
        tokens_per_step,
        wall_seconds: timer.seconds(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shards_is_rejected_before_anything_loads() {
        let cfg = TrainConfig { shards: 0, ..TrainConfig::default() };
        let err = train(&cfg).expect_err("shards=0 must be rejected");
        let msg = format!("{err:#}");
        assert!(msg.contains("shards"), "{msg}");
        assert!(msg.contains("must be >= 1"), "{msg}");
    }

    #[test]
    fn invalid_config_fails_with_every_field_named() {
        let cfg = TrainConfig {
            workers: 0,
            steps: 0,
            eval_every: 0,
            min_lr_frac: -0.5,
            worker_comp: "rank:2".into(),
            ..TrainConfig::default()
        };
        let err = train(&cfg).expect_err("invalid config must be rejected");
        let msg = format!("{err:#}");
        for field in ["workers", "steps", "eval_every", "min_lr_frac", "worker_comp"] {
            assert!(msg.contains(field), "missing {field} in: {msg}");
        }
    }
}
