//! End-to-end training orchestration: wire a [`TrainConfig`] into the
//! distributed coordinator + PJRT grad service, run the schedule, evaluate,
//! and log. This is the module behind `efmuon train` and the experiment
//! drivers in [`crate::exp`].

pub mod checkpoint;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::dist::cluster::{Cluster, ClusterCfg};
use crate::dist::coordinator::{Coordinator, CoordinatorCfg};
use crate::dist::service::GradService;
use crate::dist::{RoundMode, TransportMode};
use crate::metrics::JsonlWriter;
use crate::model::{Group, Manifest};
use crate::opt::{LayerGeometry, Schedule};
use crate::util::json::JsonObj;

/// One evaluation point on the loss curve.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub step: usize,
    pub tokens_processed: u64,
    pub w2s_bytes_per_worker: u64,
    pub eval_loss: f32,
}

/// Result of a full training run (the raw material of Figures 1–2).
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub config_comp: String,
    pub steps: usize,
    pub final_eval_loss: f32,
    pub curve: Vec<EvalPoint>,
    pub train_losses: Vec<f32>,
    pub total_w2s_bytes_per_worker: u64,
    pub total_s2w_bytes: u64,
    pub model_bytes: usize,
    pub tokens_per_step: usize,
    pub wall_seconds: f64,
}

impl TrainReport {
    /// Steps needed to first reach `target` eval loss (None = never).
    pub fn steps_to_loss(&self, target: f32) -> Option<usize> {
        self.curve.iter().find(|p| p.eval_loss <= target).map(|p| p.step)
    }

    /// Tokens needed to first reach `target` eval loss.
    pub fn tokens_to_loss(&self, target: f32) -> Option<u64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss <= target)
            .map(|p| p.tokens_processed)
    }

    /// Per-worker w2s bytes (normalized by model size) to reach `target` —
    /// the Figure 1-right / Figure 2 y-axis.
    pub fn relative_bytes_to_loss(&self, target: f32) -> Option<f64> {
        self.curve
            .iter()
            .find(|p| p.eval_loss <= target)
            .map(|p| p.w2s_bytes_per_worker as f64 / self.model_bytes as f64)
    }
}

/// Per-layer geometry with the config's group multipliers applied.
pub fn geometry_for(manifest: &Manifest, cfg: &TrainConfig) -> Vec<LayerGeometry> {
    manifest
        .layers
        .iter()
        .map(|l| {
            let mut g = l.group.geometry();
            match l.group {
                Group::Embed => g.radius_mult *= cfg.embed_mult,
                Group::Vector => g.radius_mult *= cfg.vector_mult / 0.1, // base already 0.1
                Group::Hidden => {}
            }
            g
        })
        .collect()
}

/// Run one full distributed training job per the config. `shards = 1`
/// drives the single [`Coordinator`] (the exact deployment of every prior
/// PR); `shards > 1` partitions the model's layers across a
/// [`Cluster`] of concurrent shard coordinators.
pub fn train(cfg: &TrainConfig) -> Result<TrainReport> {
    if cfg.shards == 0 {
        // reject rather than silently reinterpret as 1 (the same hardening
        // contract as RoundMode::parse)
        return Err(anyhow::anyhow!("shards must be >= 1 (got 0); use --shards 1 for the single-leader deployment"));
    }
    if cfg.shards > 1 {
        return train_cluster(cfg);
    }
    let manifest = Manifest::load(&cfg.artifacts).map_err(anyhow::Error::msg)?;
    let x0 = manifest.load_init_params().map_err(anyhow::Error::msg)?;
    let geometry = geometry_for(&manifest, cfg);
    let tokens_per_step = manifest.batch * manifest.seq_len * cfg.workers;

    let svc = GradService::spawn_pjrt(
        cfg.artifacts.clone(),
        cfg.workers,
        cfg.corpus_tokens,
        cfg.eval_batches,
        cfg.seed,
    )?;
    let mut coord = Coordinator::spawn(
        x0,
        geometry,
        svc.handle(),
        CoordinatorCfg {
            n_workers: cfg.workers,
            worker_comp: cfg.worker_comp.clone(),
            server_comp: cfg.server_comp.clone(),
            beta: cfg.beta,
            schedule: Schedule::warmup_cosine(cfg.lr, cfg.warmup, cfg.steps, cfg.min_lr_frac),
            transport: if cfg.full_codec {
                TransportMode::Encoded
            } else {
                TransportMode::Counted
            },
            round_mode: RoundMode::parse(&cfg.round_mode).map_err(anyhow::Error::msg)?,
            seed: cfg.seed,
            use_ns_artifact: cfg.use_ns_artifact,
        },
    )?;

    let mut log = match &cfg.log_path {
        Some(p) => Some(JsonlWriter::create(p)?),
        None => None,
    };
    let timer = crate::util::timer::Timer::start();
    let mut curve = Vec::new();
    let mut train_losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let stats = coord.round()?;
        // async modes: the first `lookahead` calls absorb no round yet, so
        // there is no train loss to record for them
        if stats.absorbed_step.is_some() {
            train_losses.push(stats.train_loss);
        }
        let last = step + 1 == cfg.steps;
        if last {
            // land every in-flight round before the final eval (no-op when
            // synchronous)
            for s in coord.drain()? {
                train_losses.push(s.train_loss);
            }
        }
        let do_eval = step % cfg.eval_every.max(1) == 0 || last;
        if do_eval {
            let eval_loss = coord.eval()?;
            // pair tokens with the byte meter: both count *absorbed* rounds
            // (== step+1 in sync mode; in async modes eval_loss runs at most
            // `lookahead` issued-but-unabsorbed LMO steps ahead of them)
            let absorbed = coord.meter().rounds_absorbed();
            let point = EvalPoint {
                step,
                tokens_processed: (tokens_per_step as u64) * absorbed,
                w2s_bytes_per_worker: coord.meter().w2s(),
                eval_loss,
            };
            if let Some(log) = log.as_mut() {
                let mut o = JsonObj::new()
                    .put("step", step)
                    .put("eval_loss", eval_loss)
                    .put("tokens", point.tokens_processed)
                    .put("w2s_bytes", point.w2s_bytes_per_worker)
                    .put("radius", stats.radius);
                // async modes: no train loss has landed yet in the first
                // `lookahead` rounds — omit the key rather than emit NaN
                // (which would not be valid JSON)
                if let Some(l) = train_losses.last().copied() {
                    o = o.put("train_loss", l);
                }
                log.write(&o)?;
                log.flush()?;
            }
            curve.push(point);
        }
    }

    Ok(TrainReport {
        config_comp: cfg.worker_comp.clone(),
        steps: cfg.steps,
        final_eval_loss: curve.last().map(|p| p.eval_loss).unwrap_or(f32::NAN),
        curve,
        train_losses,
        total_w2s_bytes_per_worker: coord.meter().w2s(),
        total_s2w_bytes: coord.meter().s2w(),
        model_bytes: manifest.model_bytes(),
        tokens_per_step,
        wall_seconds: timer.seconds(),
    })
}

/// The `shards > 1` training path: the model's layers are partitioned
/// across a [`Cluster`] of concurrent shard coordinators. The final eval
/// drains all shard pipelines so the reported loss reflects fully-absorbed
/// rounds on every shard.
///
/// NOTE: this loop deliberately mirrors [`train`]'s cadence (round →
/// absorbed-loss → drain at the last step only → eval → log); a change to
/// one driver's loop logic almost certainly belongs in the other too
/// (extracting a shared driver is tracked in ROADMAP.md).
fn train_cluster(cfg: &TrainConfig) -> Result<TrainReport> {
    let manifest = Manifest::load(&cfg.artifacts).map_err(anyhow::Error::msg)?;
    let x0 = manifest.load_init_params().map_err(anyhow::Error::msg)?;
    let geometry = geometry_for(&manifest, cfg);
    // the logical data workers are shared across shards (shard s's worker j
    // is data worker j), so tokens per cluster round match the
    // single-coordinator deployment
    let tokens_per_step = manifest.batch * manifest.seq_len * cfg.workers;

    let svc = GradService::spawn_pjrt(
        cfg.artifacts.clone(),
        cfg.workers,
        cfg.corpus_tokens,
        cfg.eval_batches,
        cfg.seed,
    )?;
    let mut cluster = Cluster::spawn(
        x0,
        geometry,
        svc.handle(),
        ClusterCfg {
            shards: cfg.shards,
            workers_per_shard: cfg.workers,
            worker_comp: cfg.worker_comp.clone(),
            server_comp: cfg.server_comp.clone(),
            beta: cfg.beta,
            schedule: Schedule::warmup_cosine(cfg.lr, cfg.warmup, cfg.steps, cfg.min_lr_frac),
            transport: if cfg.full_codec {
                TransportMode::Encoded
            } else {
                TransportMode::Counted
            },
            round_mode: RoundMode::parse(&cfg.round_mode).map_err(anyhow::Error::msg)?,
            seed: cfg.seed,
            use_ns_artifact: cfg.use_ns_artifact,
        },
    )?;

    let mut log = match &cfg.log_path {
        Some(p) => Some(JsonlWriter::create(p)?),
        None => None,
    };
    let timer = crate::util::timer::Timer::start();
    let mut curve = Vec::new();
    let mut train_losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let stats = cluster.round()?;
        if stats.absorbed_step.is_some() {
            train_losses.push(stats.train_loss);
        }
        let last = step + 1 == cfg.steps;
        if last {
            // the final eval drains all shard pipelines: every issued round
            // lands on every shard first (no-op when synchronous). Same
            // cadence as the single-coordinator path — mid-run evals never
            // drain, so the observation frequency (eval_every) can never
            // perturb the optimization trajectory.
            for s in cluster.drain()? {
                train_losses.push(s.train_loss);
            }
        }
        let do_eval = step % cfg.eval_every.max(1) == 0 || last;
        if do_eval {
            let eval_loss = cluster.eval()?;
            let meter = cluster.meter();
            let point = EvalPoint {
                step,
                tokens_processed: (tokens_per_step as u64) * meter.rounds_absorbed(),
                w2s_bytes_per_worker: meter.w2s(),
                eval_loss,
            };
            if let Some(log) = log.as_mut() {
                let mut o = JsonObj::new()
                    .put("step", step)
                    .put("shards", cfg.shards)
                    .put("eval_loss", eval_loss)
                    .put("tokens", point.tokens_processed)
                    .put("w2s_bytes", point.w2s_bytes_per_worker)
                    .put("s2w_bytes", meter.s2w())
                    .put("radius", stats.radius)
                    .put("meter", meter.to_json());
                if let Some(l) = train_losses.last().copied() {
                    o = o.put("train_loss", l);
                }
                log.write(&o)?;
                log.flush()?;
            }
            curve.push(point);
        }
    }

    let meter = cluster.meter();
    Ok(TrainReport {
        config_comp: cfg.worker_comp.clone(),
        steps: cfg.steps,
        final_eval_loss: curve.last().map(|p| p.eval_loss).unwrap_or(f32::NAN),
        curve,
        train_losses,
        total_w2s_bytes_per_worker: meter.w2s(),
        total_s2w_bytes: meter.s2w(),
        model_bytes: manifest.model_bytes(),
        tokens_per_step,
        wall_seconds: timer.seconds(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_shards_is_rejected_before_anything_loads() {
        let cfg = TrainConfig { shards: 0, ..TrainConfig::default() };
        let err = train(&cfg).expect_err("shards=0 must be rejected");
        assert!(format!("{err:#}").contains("shards must be >= 1"), "{err:#}");
    }
}
