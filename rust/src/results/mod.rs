//! Append-only experiment results store + reporting (EXPERIMENTS.md
//! §Results store).
//!
//! Every hotpath bench run and `exp::` sweep appends one [`Record`] —
//! `(experiment key, commit, canonical RunSpec JSON, MeterSnapshot,
//! timing summaries, trace aggregates)` — to a single JSONL file
//! (`results/results.jsonl` at the repo root, which is gitignored). The
//! `efmuon results {list,status,table,dat,gnuplot}` subcommands render the
//! accumulated history, and `scripts/bench_gate.py --results` gates new
//! timings against the stored best-ever instead of only the previous run.
//!
//! The store is deliberately dumb: append-only, one self-describing JSON
//! object per line, no index, no schema migration — a record written by an
//! older build stays readable because every field except `experiment` and
//! `commit` is optional on read. Appends happen at the CLI/bench layer,
//! never inside library functions, so `cargo test` writes nothing.
//!
//! Reporting groups history rows by [`Record::key`] — the experiment name
//! qualified by a hash of the full canonical `RunSpec` JSON — so sweeps
//! that vary more than one knob under a single experiment name stop
//! colliding in `results table` / `results latex`. Legacy records without
//! a stored spec keep the bare name as their key and still render.
//! Long histories are trimmed with [`Store::compact`] (`efmuon results
//! compact`), which always preserves the best-ever median per timing name
//! — the exact reference the trajectory gate (`bench_gate.py --results`)
//! compares against.

use std::collections::HashMap;
use std::fs::OpenOptions;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::dist::MeterSnapshot;
use crate::spec::RunSpec;
use crate::trace::TraceAgg;
use crate::util::json::{Json, JsonObj};
use crate::util::timer::BenchResult;

// ---------------------------------------------------------------------------
// Commit discovery (no subprocess: read .git directly)
// ---------------------------------------------------------------------------

/// The commit hash `HEAD` points at in the repository rooted at `root`,
/// read straight from `.git` (loose ref, then `packed-refs`, then detached
/// HEAD) — no `git` subprocess, so results stay attributable even in
/// minimal containers.
pub fn head_commit(root: &Path) -> Option<String> {
    let git = root.join(".git");
    let head = std::fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    let reference = match head.strip_prefix("ref: ") {
        None => return Some(head.to_string()), // detached HEAD: the hash itself
        Some(r) => r.trim(),
    };
    if let Ok(hash) = std::fs::read_to_string(git.join(reference)) {
        return Some(hash.trim().to_string());
    }
    // the ref may only exist packed (fresh clones, gc'd repos)
    let packed = std::fs::read_to_string(git.join("packed-refs")).ok()?;
    for line in packed.lines() {
        if line.starts_with('#') || line.starts_with('^') {
            continue;
        }
        if let Some((hash, name)) = line.split_once(' ') {
            if name.trim() == reference {
                return Some(hash.trim().to_string());
            }
        }
    }
    None
}

/// Walk up from the current directory to the repo root (the directory
/// holding `ROADMAP.md` — benches run from `rust/`, the CLI from the
/// root, tests from anywhere under it).
pub fn find_repo_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    for _ in 0..6 {
        if dir.join("ROADMAP.md").exists() || dir.join(".git").exists() {
            return Some(dir);
        }
        if !dir.pop() {
            break;
        }
    }
    None
}

/// FNV-1a 64-bit hash — stable across platforms, builds and runs, which a
/// stored history key must be (a std `Hasher` guarantees none of that).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Records
// ---------------------------------------------------------------------------

/// One timing summary inside a record (the serializable face of
/// [`BenchResult`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mad_s: f64,
    pub min_s: f64,
}

impl From<&BenchResult> for Timing {
    fn from(r: &BenchResult) -> Timing {
        Timing {
            name: r.name.clone(),
            iters: r.iters,
            median_s: r.median_s,
            mad_s: r.mad_s,
            min_s: r.min_s,
        }
    }
}

impl Timing {
    fn to_obj(&self) -> JsonObj {
        JsonObj::new()
            .put("name", self.name.as_str())
            .put("iters", self.iters)
            .put("median_s", self.median_s)
            .put("mad_s", self.mad_s)
            .put("min_s", self.min_s)
    }

    fn from_json(j: &Json) -> Result<Timing, String> {
        let num = |k: &str| j.get(k).and_then(|v| v.as_f64()).ok_or_else(|| format!("timing: missing {k}"));
        Ok(Timing {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or("timing: missing name")?
                .to_string(),
            iters: j.get("iters").and_then(|v| v.as_usize()).unwrap_or(0),
            median_s: num("median_s")?,
            mad_s: num("mad_s").unwrap_or(0.0),
            min_s: num("min_s").unwrap_or(f64::NAN),
        })
    }
}

/// One appended experiment run. `experiment` is the history key the
/// reporting CLI groups by; everything else is evidence: the commit the
/// run was built from, the canonical spec it ran, its communication
/// meters, its timing summaries and its trace aggregates.
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub experiment: String,
    pub commit: String,
    /// Seconds since the UNIX epoch at append time (0 = unknown).
    pub unix_s: u64,
    /// Canonical `RunSpec::to_json` form (a valid `--config` file).
    pub spec: Option<Json>,
    pub meter: Option<MeterSnapshot>,
    pub timings: Vec<Timing>,
    /// `TraceAgg::to_obj` form: per-phase event counts + drop counter.
    pub trace: Option<Json>,
}

impl Record {
    /// A record stamped with the current commit (best-effort) and time.
    pub fn new(experiment: impl Into<String>) -> Record {
        let commit = find_repo_root()
            .and_then(|r| head_commit(&r))
            .unwrap_or_else(|| "unknown".into());
        let unix_s = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        Record {
            experiment: experiment.into(),
            commit,
            unix_s,
            spec: None,
            meter: None,
            timings: Vec::new(),
            trace: None,
        }
    }

    pub fn spec(mut self, spec: &RunSpec) -> Record {
        self.spec = Some(spec.to_json());
        self
    }

    pub fn meter(mut self, m: MeterSnapshot) -> Record {
        self.meter = Some(m);
        self
    }

    pub fn timing(mut self, r: &BenchResult) -> Record {
        self.timings.push(Timing::from(r));
        self
    }

    pub fn trace(mut self, agg: &TraceAgg) -> Record {
        self.trace = Some(agg.to_obj().build());
        self
    }

    /// FNV-1a hash of the canonical spec JSON, when the record carries one.
    /// The canonical text is a fixed point of the JSON round trip (asserted
    /// in `rust/tests/spec_api.rs`), so the hash survives store round trips.
    pub fn spec_hash(&self) -> Option<u64> {
        self.spec.as_ref().map(|s| fnv1a64(s.to_string().as_bytes()))
    }

    /// The history key reporting groups by: `experiment#xxxxxxxx` — the
    /// experiment name qualified by the full-`RunSpec` hash — for records
    /// that carry a spec, and the bare experiment name for legacy records.
    /// Two runs of one sweep that differ in *any* spec knob therefore get
    /// distinct history keys instead of colliding under the sweep's name.
    pub fn key(&self) -> String {
        match self.spec_hash() {
            Some(h) => format!("{}#{:08x}", self.experiment, h & 0xffff_ffff),
            None => self.experiment.clone(),
        }
    }

    /// The JSONL row for this record.
    pub fn to_obj(&self) -> JsonObj {
        let mut o = JsonObj::new()
            .put("experiment", self.experiment.as_str())
            .put("commit", self.commit.as_str())
            .put("unix_s", self.unix_s);
        if let Some(s) = &self.spec {
            o = o.put("spec", s.clone());
        }
        if let Some(m) = &self.meter {
            o = o.put("meter", m.to_json());
        }
        o = o.put(
            "timings",
            Json::Arr(self.timings.iter().map(|t| t.to_obj().build()).collect()),
        );
        if let Some(t) = &self.trace {
            o = o.put("trace", t.clone());
        }
        o
    }

    /// Parse one stored row. Only `experiment` and `commit` are required —
    /// records from older builds (fewer fields) stay readable.
    pub fn from_json(j: &Json) -> Result<Record, String> {
        let s = |k: &str| -> Result<String, String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(|v| v.to_string())
                .ok_or_else(|| format!("record: missing {k}"))
        };
        let meter = match j.get("meter") {
            Some(m) => Some(MeterSnapshot::from_json(m)?),
            None => None,
        };
        let timings = match j.get("timings").and_then(|v| v.as_arr()) {
            Some(arr) => arr.iter().map(Timing::from_json).collect::<Result<Vec<_>, _>>()?,
            None => Vec::new(),
        };
        Ok(Record {
            experiment: s("experiment")?,
            commit: s("commit")?,
            unix_s: j.get("unix_s").and_then(|v| v.as_f64()).map(|v| v as u64).unwrap_or(0),
            spec: j.get("spec").cloned(),
            meter,
            timings,
            trace: j.get("trace").cloned(),
        })
    }
}

// ---------------------------------------------------------------------------
// The store
// ---------------------------------------------------------------------------

/// Append-only JSONL store of [`Record`]s.
pub struct Store {
    path: PathBuf,
}

impl Store {
    pub fn new(path: impl Into<PathBuf>) -> Store {
        Store { path: path.into() }
    }

    /// The canonical store location: `results/results.jsonl` under the
    /// repo root (falling back to the current directory when run outside
    /// the repo).
    pub fn default_path() -> PathBuf {
        find_repo_root()
            .unwrap_or_else(|| PathBuf::from("."))
            .join("results")
            .join("results.jsonl")
    }

    /// The store at [`Store::default_path`].
    pub fn open_default() -> Store {
        Store::new(Store::default_path())
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record (creates the file and parent directory on first
    /// use; never truncates — this is the one writer in the codebase that
    /// must NOT go through `JsonlWriter::create`).
    pub fn append(&self, rec: &Record) -> std::io::Result<()> {
        if let Some(parent) = self.path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = OpenOptions::new().create(true).append(true).open(&self.path)?;
        writeln!(f, "{}", rec.to_obj().to_line())
    }

    /// Every stored record, in append order. A missing file is an empty
    /// history; a malformed line is an error naming the line number (the
    /// store is evidence — fail loudly rather than silently skip).
    pub fn load(&self) -> Result<Vec<Record>, String> {
        let text = match std::fs::read_to_string(&self.path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(format!("{}: {e}", self.path.display())),
        };
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let j = Json::parse(line)
                .map_err(|e| format!("{}:{}: {e}", self.path.display(), i + 1))?;
            out.push(
                Record::from_json(&j)
                    .map_err(|e| format!("{}:{}: {e}", self.path.display(), i + 1))?,
            );
        }
        Ok(out)
    }

    /// Compact the history in place, keeping — per history key
    /// ([`Record::key`]):
    ///
    /// - the best record per `(commit, timing name)` — the run holding the
    ///   minimal stored `median_s`, so every commit keeps one
    ///   representative row per timing (retries of the same commit
    ///   collapse to the best one);
    /// - by implication, the best-ever record per timing name over the
    ///   whole history — exactly the reference the trajectory gate
    ///   (`bench_gate.py --results`) compares against, so compaction can
    ///   never loosen that gate;
    /// - the last `keep_last` records unconditionally (recent context,
    ///   including records that carry no timings at all).
    ///
    /// Survivors stay in append order. The rewrite is atomic (tmp file +
    /// rename) and skipped entirely when nothing would be dropped; a
    /// missing file is an empty history, not an error.
    pub fn compact(&self, keep_last: usize) -> Result<CompactStats, String> {
        let recs = self.load()?;
        let n = recs.len();
        let mut keep = vec![false; n];

        // the tail: last keep_last records per key, newest first
        let mut tail: HashMap<String, usize> = HashMap::new();
        for i in (0..n).rev() {
            let c = tail.entry(recs[i].key()).or_insert(0);
            if *c < keep_last {
                keep[i] = true;
                *c += 1;
            }
        }
        // best per (key, commit, timing name). The global best-ever per
        // (key, timing) is the best of its own commit, so it is always
        // among these minima — the trajectory-gate invariant.
        let mut best: HashMap<(String, String, String), (f64, usize)> = HashMap::new();
        for (i, r) in recs.iter().enumerate() {
            let k = r.key();
            for t in &r.timings {
                if !(t.median_s > 0.0) {
                    continue; // the gate ignores nonpositive medians; so do we
                }
                let e = best
                    .entry((k.clone(), r.commit.clone(), t.name.clone()))
                    .or_insert((f64::INFINITY, i));
                if t.median_s < e.0 {
                    *e = (t.median_s, i);
                }
            }
        }
        for (_, (_, i)) in best {
            keep[i] = true;
        }

        let kept: Vec<&Record> = recs.iter().zip(&keep).filter(|(_, k)| **k).map(|(r, _)| r).collect();
        let stats = CompactStats { kept: kept.len(), dropped: n - kept.len() };
        if stats.dropped == 0 {
            return Ok(stats); // leave the file untouched (also: missing file)
        }
        let tmp = self.path.with_extension("jsonl.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| format!("{}: {e}", tmp.display()))?;
            for r in &kept {
                writeln!(f, "{}", r.to_obj().to_line())
                    .map_err(|e| format!("{}: {e}", tmp.display()))?;
            }
        }
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| format!("{}: {e}", self.path.display()))?;
        Ok(stats)
    }
}

/// Outcome of [`Store::compact`]: how many records survived and how many
/// were dropped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactStats {
    pub kept: usize,
    pub dropped: usize,
}

// ---------------------------------------------------------------------------
// Reporting (pure renderers — the `efmuon results` subcommands)
// ---------------------------------------------------------------------------

/// Unique experiment keys in first-seen order.
pub fn experiments(records: &[Record]) -> Vec<&str> {
    let mut seen: Vec<&str> = Vec::new();
    for r in records {
        if !seen.contains(&r.experiment.as_str()) {
            seen.push(&r.experiment);
        }
    }
    seen
}

/// Unique history keys ([`Record::key`]) in first-seen order — the
/// partition `results latex` emits one table per.
pub fn history_keys(records: &[Record]) -> Vec<String> {
    let mut seen: Vec<String> = Vec::new();
    for r in records {
        let k = r.key();
        if !seen.contains(&k) {
            seen.push(k);
        }
    }
    seen
}

fn short(commit: &str) -> &str {
    &commit[..commit.len().min(9)]
}

fn fmt_ms(s: f64) -> String {
    format!("{:.3}", s * 1e3)
}

/// `results list`: one row per experiment key with run counts and the
/// commit span of its history.
pub fn render_list(records: &[Record]) -> String {
    let rows: Vec<Vec<String>> = experiments(records)
        .iter()
        .map(|key| {
            let runs: Vec<&Record> =
                records.iter().filter(|r| r.experiment == *key).collect();
            vec![
                key.to_string(),
                runs.len().to_string(),
                short(&runs[0].commit).to_string(),
                short(&runs[runs.len() - 1].commit).to_string(),
            ]
        })
        .collect();
    crate::metrics::render_table(&["experiment", "runs", "first", "latest"], &rows)
}

/// `results status`: the latest record of every experiment at a glance.
pub fn render_status(records: &[Record]) -> String {
    let rows: Vec<Vec<String>> = experiments(records)
        .iter()
        .map(|key| {
            let last = records
                .iter()
                .rev()
                .find(|r| r.experiment == *key)
                .expect("key came from records");
            let best = last
                .timings
                .iter()
                .map(|t| t.median_s)
                .fold(f64::INFINITY, f64::min);
            let rounds = last
                .meter
                .as_ref()
                .map(|m| m.rounds_absorbed.to_string())
                .unwrap_or_else(|| "-".into());
            let events = last
                .trace
                .as_ref()
                .and_then(|t| t.get("events"))
                .and_then(|v| v.as_f64())
                .map(|v| (v as u64).to_string())
                .unwrap_or_else(|| "-".into());
            vec![
                key.to_string(),
                short(&last.commit).to_string(),
                last.timings.len().to_string(),
                if best.is_finite() { fmt_ms(best) } else { "-".into() },
                rounds,
                events,
            ]
        })
        .collect();
    crate::metrics::render_table(
        &["experiment", "commit", "timings", "best ms", "rounds", "trace ev"],
        &rows,
    )
}

/// Column headers of the per-key history (shared by `results table` and
/// `results latex`). `spec` is the short `RunSpec` hash of [`Record::key`]
/// (`-` for legacy records without a stored spec).
const HISTORY_HEADERS: [&str; 9] =
    ["run", "commit", "spec", "timing", "median ms", "mad ms", "min ms", "iters", "rounds"];

/// The shared row model of `results table` and `results latex`: one row
/// per (run, timing) of the selected records, in append order. Both
/// renderers consume exactly these rows, so the LaTeX output can never
/// drift from the plain table.
fn history_rows_by(records: &[Record], matches: impl Fn(&Record) -> bool) -> Vec<Vec<String>> {
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (run, r) in records.iter().filter(|r| matches(r)).enumerate() {
        for t in &r.timings {
            let rounds = r
                .meter
                .as_ref()
                .map(|m| m.rounds_absorbed.to_string())
                .unwrap_or_else(|| "-".into());
            let spec = r
                .spec_hash()
                .map(|h| format!("{:08x}", h & 0xffff_ffff))
                .unwrap_or_else(|| "-".into());
            rows.push(vec![
                run.to_string(),
                short(&r.commit).to_string(),
                spec,
                t.name.clone(),
                fmt_ms(t.median_s),
                fmt_ms(t.mad_s),
                fmt_ms(t.min_s),
                t.iters.to_string(),
                rounds,
            ]);
        }
    }
    rows
}

/// Rows for one selector: an exact history key (`name#hash`) narrows to
/// that spec; a bare experiment name keeps the legacy behavior and shows
/// every record appended under it (the `spec` column disambiguates).
fn history_rows(records: &[Record], key: &str) -> Vec<Vec<String>> {
    history_rows_by(records, |r| r.key() == key || r.experiment == key)
}

/// `results table KEY`: the full history of one experiment (bare name) or
/// one exact spec-qualified key (`name#hash`), one row per (run, timing).
pub fn render_history(records: &[Record], experiment: &str) -> String {
    let rows = history_rows(records, experiment);
    if rows.is_empty() {
        return format!("no runs recorded for experiment {experiment:?}\n");
    }
    crate::metrics::render_table(&HISTORY_HEADERS, &rows)
}

/// Minimal LaTeX escaping for text cells (experiment keys, timing names,
/// commit hashes).
fn latex_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '&' | '%' | '$' | '#' | '_' | '{' | '}' => {
                out.push('\\');
                out.push(c);
            }
            '\\' => out.push_str("\\textbackslash{}"),
            '~' => out.push_str("\\textasciitilde{}"),
            '^' => out.push_str("\\textasciicircum{}"),
            _ => out.push(c),
        }
    }
    out
}

/// `results latex`: the whole stored history as LaTeX — one `tabular` per
/// history key ([`Record::key`]; spec-qualified, so sweeps varying more
/// than one knob get one table per distinct spec while legacy name-keyed
/// records keep their own), built from the exact row model of
/// `results table` ([`history_rows_by`]), so a paper draft can cite the
/// stored evidence verbatim. Plain `\hline` rules — no package
/// dependencies.
pub fn render_latex(records: &[Record]) -> String {
    let keys = history_keys(records);
    if keys.is_empty() {
        return "% no experiment history recorded\n".to_string();
    }
    let mut out = String::from("% generated by `efmuon results latex`\n");
    for key in &keys {
        let rows = history_rows_by(records, |r| r.key() == *key);
        if rows.is_empty() {
            out.push_str(&format!("% experiment {}: no timings recorded\n", latex_escape(key)));
            continue;
        }
        out.push_str(&format!(
            "\n\\begin{{table}}[ht]\n  \\centering\n  \\caption{{Experiment \
             \\texttt{{{}}}: stored timing history}}\n  \
             \\begin{{tabular}}{{llllrrrrr}}\n    \\hline\n",
            latex_escape(key)
        ));
        out.push_str(&format!(
            "    {} \\\\\n    \\hline\n",
            HISTORY_HEADERS.map(latex_escape).join(" & ")
        ));
        for row in rows {
            let cells: Vec<String> = row.iter().map(|c| latex_escape(c)).collect();
            out.push_str(&format!("    {} \\\\\n", cells.join(" & ")));
        }
        out.push_str("    \\hline\n  \\end{tabular}\n\\end{table}\n");
    }
    out
}

/// `results dat KEY`: the same history as whitespace-separated columns
/// (run index, median seconds, min seconds, commit, timing name) — the
/// file format the gnuplot script consumes.
pub fn render_dat(records: &[Record], experiment: &str) -> String {
    let mut out = String::from("# run median_s min_s commit timing\n");
    for (run, r) in records.iter().filter(|r| r.experiment == experiment).enumerate() {
        for t in &r.timings {
            out.push_str(&format!(
                "{} {:.9} {:.9} {} {:?}\n",
                run,
                t.median_s,
                t.min_s,
                short(&r.commit),
                t.name
            ));
        }
    }
    out
}

/// `results gnuplot KEY`: a self-contained gnuplot script plotting the
/// median trend over the stored history (pipe `results dat` to the file it
/// names).
pub fn render_gnuplot(experiment: &str) -> String {
    let dat = format!("{experiment}.dat");
    format!(
        "# gnuplot script for experiment {experiment:?}\n\
         # generate the data file first:  efmuon results dat {experiment} > {dat}\n\
         set title \"{experiment}: median round time by run\"\n\
         set xlabel \"run (append order)\"\n\
         set ylabel \"seconds\"\n\
         set grid\n\
         plot \"{dat}\" using 1:2 with linespoints title \"median\", \\\n\
              \"{dat}\" using 1:3 with points title \"min\"\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::timer::BenchResult;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("efmuon_results_{name}"))
    }

    fn bench(name: &str, median: f64) -> BenchResult {
        BenchResult {
            name: name.into(),
            iters: 5,
            median_s: median,
            mad_s: median * 0.01,
            min_s: median * 0.9,
        }
    }

    #[test]
    fn record_roundtrips_with_every_field() {
        let spec = RunSpec::default();
        let meter = MeterSnapshot { rounds_absorbed: 7, w2s_per_worker: 123, ..Default::default() };
        let mut agg = TraceAgg::default();
        agg.events = 3;
        let rec = Record::new("hotpath")
            .spec(&spec)
            .meter(meter)
            .timing(&bench("coordinator round", 0.01))
            .trace(&agg);
        let line = rec.to_obj().to_line();
        let back = Record::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.timings.len(), 1);
        assert_eq!(back.meter.unwrap().rounds_absorbed, 7);
        // minimal legacy row still parses
        let old = Json::parse(r#"{"experiment":"x","commit":"abc"}"#).unwrap();
        let r = Record::from_json(&old).unwrap();
        assert!(r.timings.is_empty() && r.meter.is_none() && r.spec.is_none());
        // required keys really are required
        assert!(Record::from_json(&Json::parse(r#"{"commit":"abc"}"#).unwrap()).is_err());
    }

    #[test]
    fn store_appends_and_table_renders_two_runs_of_one_key() {
        let dir = tmp("append");
        let _ = std::fs::remove_dir_all(&dir);
        let store = Store::new(dir.join("results.jsonl"));
        assert!(store.load().unwrap().is_empty(), "missing file = empty history");
        let mut r1 = Record::new("hotpath");
        r1.commit = "aaaaaaaaaaaa".into();
        let mut r2 = Record::new("hotpath");
        r2.commit = "bbbbbbbbbbbb".into();
        store.append(&r1.timing(&bench("coordinator round", 0.010))).unwrap();
        store.append(&r2.timing(&bench("coordinator round", 0.009))).unwrap();
        store.append(&Record::new("other")).unwrap();
        let recs = store.load().unwrap();
        assert_eq!(recs.len(), 3, "append must not truncate");
        assert_eq!(experiments(&recs), vec!["hotpath", "other"]);
        // the acceptance render: >= 2 appended runs of the same key
        let table = render_history(&recs, "hotpath");
        assert!(table.contains("aaaaaaaaa"), "{table}");
        assert!(table.contains("bbbbbbbbb"), "{table}");
        assert_eq!(table.matches("coordinator round").count(), 2, "{table}");
        assert!(render_list(&recs).contains("hotpath"));
        assert!(render_status(&recs).contains("other"));
        let dat = render_dat(&recs, "hotpath");
        assert_eq!(dat.lines().count(), 3, "header + 2 runs: {dat}");
        assert!(render_gnuplot("hotpath").contains("hotpath.dat"));
        assert!(render_history(&recs, "missing").contains("no runs"));
    }

    #[test]
    fn latex_shares_the_table_row_model() {
        let mut r1 = Record::new("hot_path");
        r1.commit = "aaaaaaaaaaaa".into();
        let recs = vec![r1.timing(&bench("coordinator round", 0.010)), Record::new("empty_key")];
        let tex = render_latex(&recs);
        assert!(tex.contains("\\begin{tabular}{llllrrrrr}"), "{tex}");
        assert!(tex.contains("hot\\_path"), "underscores must be escaped: {tex}");
        assert!(tex.contains("0 & aaaaaaaaa & - & coordinator round & 10.000"), "{tex}");
        assert!(tex.contains("% experiment empty\\_key: no timings recorded"), "{tex}");
        assert_eq!(tex.matches("\\end{table}").count(), 1, "one tabular per experiment: {tex}");
        assert_eq!(render_latex(&[]), "% no experiment history recorded\n");
    }

    #[test]
    fn spec_hash_keys_split_sweeps_and_legacy_names_still_render() {
        use crate::spec::RunBuilder;
        let mut a = Record::new("sweep");
        a.commit = "aaaaaaaaaaaa".into();
        let a = a.spec(&RunSpec::default()).timing(&bench("cluster round", 0.010));
        let spec2 = RunBuilder::new().workers(8).build().unwrap();
        let mut b = Record::new("sweep");
        b.commit = "bbbbbbbbbbbb".into();
        let b = b.spec(&spec2).timing(&bench("cluster round", 0.008));
        let mut legacy = Record::new("sweep");
        legacy.commit = "cccccccccccc".into();
        let legacy = legacy.timing(&bench("cluster round", 0.007));
        let recs = vec![a.clone(), b.clone(), legacy.clone()];
        // distinct specs get distinct keys under the same experiment name
        assert_ne!(a.key(), b.key());
        assert!(a.key().starts_with("sweep#"), "{}", a.key());
        assert_eq!(legacy.key(), "sweep");
        assert_eq!(history_keys(&recs).len(), 3);
        // table by exact key narrows to the one spec
        let t = render_history(&recs, &a.key());
        assert!(t.contains("aaaaaaaaa") && !t.contains("bbbbbbbbb"), "{t}");
        // table by bare name still renders everything (legacy workflow);
        // the spec column disambiguates
        let t = render_history(&recs, "sweep");
        for c in ["aaaaaaaaa", "bbbbbbbbb", "ccccccccc"] {
            assert!(t.contains(c), "{t}");
        }
        // latex partitions by key: one tabular per spec, plus the legacy one
        let tex = render_latex(&recs);
        assert_eq!(tex.matches("\\end{table}").count(), 3, "{tex}");
        // the key survives a store round trip (hash of canonical JSON text)
        let line = a.to_obj().to_line();
        let back = Record::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back.key(), a.key());
    }

    #[test]
    fn compact_keeps_best_per_commit_and_tail() {
        let dir = tmp("compact");
        let _ = std::fs::remove_dir_all(&dir);
        let store = Store::new(dir.join("results.jsonl"));
        // a missing file compacts to an empty no-op
        assert_eq!(store.compact(2).unwrap(), CompactStats { kept: 0, dropped: 0 });
        // index 0: an old record with no timings (only the tail could keep
        // it); 1-2: two runs of commit bbbb, the first holding the
        // best-ever median; 3-5: one run each of three later commits
        let mut r0 = Record::new("hotpath");
        r0.commit = "000000000000".into();
        store.append(&r0).unwrap();
        let runs = [
            (0.005, "bbbbbbbbbbbb"),
            (0.009, "bbbbbbbbbbbb"),
            (0.011, "cccccccccccc"),
            (0.012, "dddddddddddd"),
            (0.013, "eeeeeeeeeeee"),
        ];
        for (m, c) in runs {
            let mut r = Record::new("hotpath");
            r.commit = c.into();
            store.append(&r.timing(&bench("cluster round", m))).unwrap();
        }
        let st = store.compact(2).unwrap();
        // dropped: the timing-less head and the worse bbbb retry; kept:
        // best-per-commit (bbbb 0.005, cccc, dddd, eeee), tail covered
        assert_eq!(st, CompactStats { kept: 4, dropped: 2 });
        let recs = store.load().unwrap();
        assert_eq!(recs.len(), 4);
        // the trajectory gate's reference — best-ever per timing — survives
        let best = recs
            .iter()
            .flat_map(|r| &r.timings)
            .map(|t| t.median_s)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(best, 0.005, "best-ever median must survive compaction");
        // append order preserved; the last two records are the tail
        assert_eq!(recs[recs.len() - 1].commit, "eeeeeeeeeeee");
        assert_eq!(recs[recs.len() - 2].commit, "dddddddddddd");
        assert!(!recs.iter().any(|r| r.commit == "000000000000"));
        assert_eq!(recs.iter().filter(|r| r.commit == "bbbbbbbbbbbb").count(), 1);
        // idempotent: a second pass drops nothing
        assert_eq!(store.compact(2).unwrap(), CompactStats { kept: 4, dropped: 0 });
    }

    #[test]
    fn malformed_line_errors_with_line_number() {
        let dir = tmp("malformed");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("results.jsonl");
        std::fs::write(
            &path,
            "{\"experiment\":\"a\",\"commit\":\"c\"}\nnot json at all\n",
        )
        .unwrap();
        let err = Store::new(&path).load().unwrap_err();
        assert!(err.contains(":2:"), "line number missing: {err}");
        // a JSON line missing required keys also names its line
        std::fs::write(&path, "{\"commit\":\"c\"}\n").unwrap();
        let err = Store::new(&path).load().unwrap_err();
        assert!(err.contains(":1:") && err.contains("experiment"), "{err}");
    }

    #[test]
    fn head_commit_reads_loose_packed_and_detached() {
        let root = tmp("gitread");
        let _ = std::fs::remove_dir_all(&root);
        let git = root.join(".git");
        std::fs::create_dir_all(git.join("refs/heads")).unwrap();
        // loose ref
        std::fs::write(git.join("HEAD"), "ref: refs/heads/main\n").unwrap();
        std::fs::write(git.join("refs/heads/main"), "abc123\n").unwrap();
        assert_eq!(head_commit(&root).as_deref(), Some("abc123"));
        // packed ref (loose file removed)
        std::fs::remove_file(git.join("refs/heads/main")).unwrap();
        std::fs::write(
            git.join("packed-refs"),
            "# pack-refs with: peeled\ndef456 refs/heads/main\n",
        )
        .unwrap();
        assert_eq!(head_commit(&root).as_deref(), Some("def456"));
        // detached HEAD
        std::fs::write(git.join("HEAD"), "0123abcd\n").unwrap();
        assert_eq!(head_commit(&root).as_deref(), Some("0123abcd"));
    }
}
