//! Training configuration: JSON config files + CLI overrides (flags win).
//!
//! `TrainConfig` is the **string-level serialization facade** over the
//! typed [`crate::spec::RunSpec`]: every field that names an algorithm
//! choice (`worker_comp`, `round_mode`, `lmo_hidden`, …) is a plain string
//! here and is parsed **exactly once** — by
//! [`crate::spec::RunBuilder::from_config`] (via [`TrainConfig::validate`])
//! — into the typed descriptor the rest of the system runs on. Nothing
//! outside the `spec`/`config` boundary ever re-parses these strings.
//!
//! Every experiment in `rust/benches` and `examples/` is a `TrainConfig`;
//! the same struct drives the `efmuon train` subcommand, and
//! `efmuon config` prints the validated spec back as canonical JSON
//! (a lossless `RunSpec → Json → RunSpec` round trip).

use crate::spec::{RunBuilder, RunSpec, SpecError};
use crate::util::cli::Args;
use crate::util::json::Json;

/// Full configuration of one distributed training run (string facade; see
/// the module docs and [`crate::spec::RunSpec`] for the typed form).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Directory with `manifest.json` + HLO artifacts.
    pub artifacts: String,
    /// Number of workers `n` (the paper uses 4 GPUs → 4 workers).
    pub workers: usize,
    /// Shard coordinators the model's layers are partitioned across (see
    /// [`crate::dist::cluster`]). `1` = the single-leader deployment;
    /// `N > 1` runs N concurrent leaders, each with its own `workers`-sized
    /// worker pool, reduced by a root coordinator.
    pub shards: usize,
    /// Optimizer steps.
    pub steps: usize,
    /// Worker (w2s) compressor spec, e.g. `rank:0.15+nat` (see
    /// [`crate::compress::parse_spec`]).
    pub worker_comp: String,
    /// Server (s2w) compressor spec for the EF21-P broadcast. Any
    /// contractive spec works end to end (bidirectional compression); `id`
    /// reproduces the paper's dense-broadcast deployment.
    pub server_comp: String,
    /// Round scheduling: `sync` | `async` (= `async:1`) | `async:N` —
    /// see [`crate::dist::RoundMode`]. `async:0` is bit-equal to `sync`.
    pub round_mode: String,
    /// LMO ball for the hidden (2-D matmul) group: `spectral` | `sign` |
    /// `top1` | `euclid` | `nuclear` | `colnorm`. The defaults are the
    /// paper's assignment; presets pin them to recover Muon/Scion/Gluon
    /// (see [`crate::spec::Preset`]).
    pub lmo_hidden: String,
    /// LMO ball for the embedding / tied-output group.
    pub lmo_embed: String,
    /// LMO ball for the vector (LayerNorm gain) group.
    pub lmo_vector: String,
    /// Momentum β (paper uses 0.9).
    pub beta: f32,
    /// Base radius / learning rate for hidden layers.
    pub lr: f64,
    /// Radius multiplier for the embed group.
    pub embed_mult: f32,
    /// Radius multiplier for the vector (LayerNorm gain) group.
    pub vector_mult: f32,
    /// Warmup steps for the nanoGPT-style scheduler.
    pub warmup: usize,
    /// Final LR fraction of the cosine schedule.
    pub min_lr_frac: f64,
    /// Synthetic corpus size in tokens.
    pub corpus_tokens: usize,
    /// Evaluate every `eval_every` steps.
    pub eval_every: usize,
    /// Number of held-out eval batches.
    pub eval_batches: usize,
    /// Use the PJRT NS artifact (Pallas kernel) for spectral LMOs when a
    /// matching shape exists; falls back to native NS otherwise.
    pub use_ns_artifact: bool,
    /// Run the real wire codec (encode+decode) on every message instead of
    /// analytic byte counting — slower, bit-exact transport simulation.
    pub full_codec: bool,
    pub seed: u64,
    /// Optional JSONL metrics path.
    pub log_path: Option<String>,
    /// Optional round-phase trace JSONL path (`--trace PATH`); `None`
    /// keeps the zero-cost `Tracer::Noop` path.
    pub trace_path: Option<String>,
    /// Straggler / quorum / respawn policy spec: `off`, or a comma list of
    /// `deadline:MS,quorum:F,respawns:N,backoff:MS` (see
    /// [`crate::dist::fault::FaultPolicy`]).
    pub fault_policy: String,
    /// Save a checkpoint every this many steps (0 = never).
    pub checkpoint_every: usize,
    /// Directory checkpoints are saved to / resumed from.
    pub checkpoint_dir: Option<String>,
    /// Resume from the latest checkpoint in `checkpoint_dir`.
    pub resume: bool,
    /// Schedule shape: `warmup-cosine` (default) | `constant` |
    /// `inv-sqrt-total` | `theory34` (see [`crate::spec::SchedulePlan`]).
    pub schedule: String,
    /// Transport of the leader/worker hop: `channel` (in-process, default)
    /// or `tcp:ADDR` (the socket transport; see [`crate::dist::net`]).
    pub transport: String,
    /// Bounded-epoch shard scheduling spec: `off` (lock-step, default) or
    /// `window:N[,steal:T|steal:off]` — shards may run up to `N` rounds
    /// ahead of the slowest; `steal:T` migrates a layer off a shard whose
    /// EWMA round time exceeds `T`× the fastest shard's (see
    /// [`crate::dist::sched::SchedSpec`]). Requires `shards >= 2`.
    pub sched: String,
    /// Store parameter-board epoch snapshots in bf16 (`--snap-bf16`):
    /// half the snapshot memory; readers expand back to f32.
    pub snap_bf16: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            artifacts: "artifacts".into(),
            workers: 4,
            shards: 1,
            steps: 200,
            worker_comp: "id".into(),
            server_comp: "id".into(),
            round_mode: "sync".into(),
            lmo_hidden: "spectral".into(),
            lmo_embed: "sign".into(),
            lmo_vector: "sign".into(),
            beta: 0.9,
            lr: 0.02,
            embed_mult: 1.0,
            vector_mult: 0.1,
            warmup: 20,
            min_lr_frac: 0.1,
            corpus_tokens: 2_000_000,
            eval_every: 25,
            eval_batches: 4,
            use_ns_artifact: true,
            full_codec: false,
            seed: 0,
            log_path: None,
            trace_path: None,
            fault_policy: "off".into(),
            checkpoint_every: 0,
            checkpoint_dir: None,
            resume: false,
            schedule: "warmup-cosine".into(),
            transport: "channel".into(),
            sched: "off".into(),
            snap_bf16: false,
        }
    }
}

impl TrainConfig {
    /// Apply CLI flag overrides on top of `self`. A malformed or dangling
    /// numeric flag (`--lr` with no value) is a usage `Err`, not a panic.
    pub fn override_from_args(mut self, a: &Args) -> Result<Self, String> {
        self.artifacts = a.str("artifacts", &self.artifacts);
        self.workers = a.usize("workers", self.workers)?;
        self.shards = a.usize("shards", self.shards)?;
        self.steps = a.usize("steps", self.steps)?;
        self.worker_comp = a.str("comp", &self.worker_comp);
        self.server_comp = a.str("server-comp", &self.server_comp);
        self.round_mode = a.str("round-mode", &self.round_mode);
        self.lmo_hidden = a.str("lmo-hidden", &self.lmo_hidden);
        self.lmo_embed = a.str("lmo-embed", &self.lmo_embed);
        self.lmo_vector = a.str("lmo-vector", &self.lmo_vector);
        self.beta = a.f64("beta", self.beta as f64)? as f32;
        self.lr = a.f64("lr", self.lr)?;
        self.embed_mult = a.f64("embed-mult", self.embed_mult as f64)? as f32;
        self.vector_mult = a.f64("vector-mult", self.vector_mult as f64)? as f32;
        self.warmup = a.usize("warmup", self.warmup)?;
        self.min_lr_frac = a.f64("min-lr-frac", self.min_lr_frac)?;
        self.corpus_tokens = a.usize("corpus-tokens", self.corpus_tokens)?;
        self.eval_every = a.usize("eval-every", self.eval_every)?;
        self.eval_batches = a.usize("eval-batches", self.eval_batches)?;
        self.use_ns_artifact = a.bool("ns-artifact", self.use_ns_artifact);
        self.full_codec = a.bool("full-codec", self.full_codec);
        self.seed = a.u64("seed", self.seed)?;
        if let Some(p) = a.opt_str("log") {
            self.log_path = Some(p);
        }
        if let Some(p) = a.opt_str("trace") {
            self.trace_path = Some(p);
        }
        self.fault_policy = a.str("fault-policy", &self.fault_policy);
        self.checkpoint_every = a.usize("checkpoint-every", self.checkpoint_every)?;
        if let Some(d) = a.opt_str("checkpoint-dir") {
            self.checkpoint_dir = Some(d);
        }
        self.resume = a.bool("resume", self.resume);
        self.schedule = a.str("schedule", &self.schedule);
        self.transport = a.str("transport", &self.transport);
        self.sched = a.str("sched", &self.sched);
        self.snap_bf16 = a.bool("snap-bf16", self.snap_bf16);
        Ok(self)
    }

    /// Load overrides from a JSON config file (missing keys keep defaults).
    pub fn from_json(text: &str) -> Result<TrainConfig, String> {
        let j = Json::parse(text)?;
        let mut c = TrainConfig::default();
        let obj = j.as_obj().ok_or("config must be a JSON object")?;
        for (k, v) in obj {
            match k.as_str() {
                "artifacts" => c.artifacts = v.as_str().ok_or("artifacts: string")?.into(),
                "workers" => c.workers = v.as_usize().ok_or("workers: int")?,
                "shards" => c.shards = v.as_usize().ok_or("shards: int")?,
                "steps" => c.steps = v.as_usize().ok_or("steps: int")?,
                "worker_comp" => c.worker_comp = v.as_str().ok_or("worker_comp: string")?.into(),
                "server_comp" => c.server_comp = v.as_str().ok_or("server_comp: string")?.into(),
                "round_mode" => c.round_mode = v.as_str().ok_or("round_mode: string")?.into(),
                "lmo_hidden" => c.lmo_hidden = v.as_str().ok_or("lmo_hidden: string")?.into(),
                "lmo_embed" => c.lmo_embed = v.as_str().ok_or("lmo_embed: string")?.into(),
                "lmo_vector" => c.lmo_vector = v.as_str().ok_or("lmo_vector: string")?.into(),
                "beta" => c.beta = v.as_f64().ok_or("beta: number")? as f32,
                "lr" => c.lr = v.as_f64().ok_or("lr: number")?,
                "embed_mult" => c.embed_mult = v.as_f64().ok_or("embed_mult: number")? as f32,
                "vector_mult" => c.vector_mult = v.as_f64().ok_or("vector_mult: number")? as f32,
                "warmup" => c.warmup = v.as_usize().ok_or("warmup: int")?,
                "min_lr_frac" => c.min_lr_frac = v.as_f64().ok_or("min_lr_frac: number")?,
                "corpus_tokens" => c.corpus_tokens = v.as_usize().ok_or("corpus_tokens: int")?,
                "eval_every" => c.eval_every = v.as_usize().ok_or("eval_every: int")?,
                "eval_batches" => c.eval_batches = v.as_usize().ok_or("eval_batches: int")?,
                "use_ns_artifact" => c.use_ns_artifact = v.as_bool().ok_or("use_ns_artifact: bool")?,
                "full_codec" => c.full_codec = v.as_bool().ok_or("full_codec: bool")?,
                "seed" => c.seed = v.as_f64().ok_or("seed: number")? as u64,
                "log_path" => c.log_path = v.as_str().map(|s| s.to_string()),
                "trace_path" => c.trace_path = v.as_str().map(|s| s.to_string()),
                "fault_policy" => {
                    c.fault_policy = v.as_str().ok_or("fault_policy: string")?.into()
                }
                "checkpoint_every" => {
                    c.checkpoint_every = v.as_usize().ok_or("checkpoint_every: int")?
                }
                "checkpoint_dir" => c.checkpoint_dir = v.as_str().map(|s| s.to_string()),
                "resume" => c.resume = v.as_bool().ok_or("resume: bool")?,
                "schedule" => c.schedule = v.as_str().ok_or("schedule: string")?.into(),
                "transport" => c.transport = v.as_str().ok_or("transport: string")?.into(),
                "sched" => c.sched = v.as_str().ok_or("sched: string")?.into(),
                "snap_bf16" => c.snap_bf16 = v.as_bool().ok_or("snap_bf16: bool")?,
                other => return Err(format!("unknown config key {other:?}")),
            }
        }
        Ok(c)
    }

    /// Parse every string field exactly once and validate every numeric
    /// invariant eagerly, returning the typed [`RunSpec`] — or a
    /// [`SpecError`] naming *all* offending fields by path. This is the one
    /// boundary between the string facade and the typed world; `train`
    /// calls it before anything loads, so `workers = 0`, `eval_every = 0`,
    /// `steps = 0` or an out-of-range `min_lr_frac` fail here with a field
    /// message instead of surfacing as late panics or silent div-by-zero
    /// deep in the run.
    pub fn validate(&self) -> Result<RunSpec, SpecError> {
        RunBuilder::from_config(self).build()
    }

    /// Parse `--config file.json` (if given) then CLI overrides.
    pub fn from_args(a: &Args) -> Result<TrainConfig, String> {
        let base = match a.opt_str("config") {
            Some(path) => {
                let text = std::fs::read_to_string(&path)
                    .map_err(|e| format!("reading config {path}: {e}"))?;
                TrainConfig::from_json(&text)?
            }
            None => TrainConfig::default(),
        };
        base.override_from_args(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_overrides() {
        let c = TrainConfig::from_json(
            r#"{"workers": 8, "worker_comp": "rank:0.1+nat", "lr": 0.05,
                "server_comp": "top:0.5", "round_mode": "async:2", "shards": 3}"#,
        )
        .unwrap();
        assert_eq!(c.workers, 8);
        assert_eq!(c.shards, 3);
        assert_eq!(c.worker_comp, "rank:0.1+nat");
        assert_eq!(c.server_comp, "top:0.5");
        assert_eq!(c.round_mode, "async:2");
        assert_eq!(c.lr, 0.05);
        assert_eq!(c.steps, TrainConfig::default().steps);
        assert!(TrainConfig::from_json(r#"{"bogus": 1}"#).is_err());
    }

    #[test]
    fn fault_and_checkpoint_keys_parse() {
        let c = TrainConfig::from_json(
            r#"{"fault_policy": "deadline:50,quorum:0.75,respawns:2,backoff:5",
                "checkpoint_every": 10, "checkpoint_dir": "/tmp/ck", "resume": true,
                "trace_path": "/tmp/trace.jsonl"}"#,
        )
        .unwrap();
        assert_eq!(c.fault_policy, "deadline:50,quorum:0.75,respawns:2,backoff:5");
        assert_eq!(c.trace_path.as_deref(), Some("/tmp/trace.jsonl"));
        assert_eq!(c.checkpoint_every, 10);
        assert_eq!(c.checkpoint_dir.as_deref(), Some("/tmp/ck"));
        assert!(c.resume);
        let a = Args::parse(
            ["--fault-policy", "deadline:25", "--checkpoint-every", "5",
             "--checkpoint-dir", "out/ck", "--resume", "--trace", "out/trace.jsonl"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().override_from_args(&a).unwrap();
        assert_eq!(c.fault_policy, "deadline:25");
        assert_eq!(c.trace_path.as_deref(), Some("out/trace.jsonl"));
        assert_eq!(c.checkpoint_every, 5);
        assert_eq!(c.checkpoint_dir.as_deref(), Some("out/ck"));
        assert!(c.resume);
    }

    #[test]
    fn schedule_and_transport_keys_parse() {
        let c = TrainConfig::from_json(
            r#"{"schedule": "theory34", "transport": "tcp:127.0.0.1:4310"}"#,
        )
        .unwrap();
        assert_eq!(c.schedule, "theory34");
        assert_eq!(c.transport, "tcp:127.0.0.1:4310");
        let a = Args::parse(
            ["--schedule", "inv-sqrt-total", "--transport", "tcp:0.0.0.0:9"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().override_from_args(&a).unwrap();
        assert_eq!(c.schedule, "inv-sqrt-total");
        assert_eq!(c.transport, "tcp:0.0.0.0:9");
        // defaults validate to the default spec (nothing new required)
        assert_eq!(TrainConfig::default().schedule, "warmup-cosine");
        assert_eq!(TrainConfig::default().transport, "channel");
        let err = TrainConfig { transport: "carrier-pigeon".into(), ..TrainConfig::default() }
            .validate()
            .unwrap_err();
        assert!(err.mentions("transport"), "{err}");
    }

    #[test]
    fn sched_and_snap_bf16_keys_parse() {
        let c = TrainConfig::from_json(
            r#"{"sched": "window:2,steal:1.5", "snap_bf16": true, "shards": 2}"#,
        )
        .unwrap();
        assert_eq!(c.sched, "window:2,steal:1.5");
        assert!(c.snap_bf16);
        let a = Args::parse(
            ["--sched", "window:1", "--snap-bf16", "--shards", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::default().override_from_args(&a).unwrap();
        assert_eq!(c.sched, "window:1");
        assert!(c.snap_bf16);
        assert_eq!(c.shards, 2);
        // defaults validate to the default spec
        assert_eq!(TrainConfig::default().sched, "off");
        assert!(!TrainConfig::default().snap_bf16);
        let err = TrainConfig { sched: "window:-3".into(), shards: 2, ..TrainConfig::default() }
            .validate()
            .unwrap_err();
        assert!(err.mentions("sched"), "{err}");
    }

    #[test]
    fn validate_is_the_typed_boundary() {
        let spec = TrainConfig::default().validate().unwrap();
        assert!(spec.worker_comp.is_identity());
        assert_eq!(spec, RunSpec::default());
        let bad = TrainConfig {
            workers: 0,
            worker_comp: "top:9".into(),
            lmo_embed: "l33t".into(),
            ..TrainConfig::default()
        };
        let err = bad.validate().unwrap_err();
        assert!(err.mentions("workers"), "{err}");
        assert!(err.mentions("worker_comp"), "{err}");
        assert!(err.mentions("lmo_embed"), "{err}");
    }

    #[test]
    fn cli_overrides_win() {
        let a = Args::parse(
            ["--steps", "7", "--comp", "top:0.2", "--seed", "42",
             "--round-mode", "async:1", "--shards", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        let c = TrainConfig::from_args(&a).unwrap();
        assert_eq!(c.steps, 7);
        assert_eq!(c.shards, 2);
        assert_eq!(c.worker_comp, "top:0.2");
        assert_eq!(c.round_mode, "async:1");
        assert_eq!(c.seed, 42);
    }
}
