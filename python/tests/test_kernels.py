"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes (including non-tile-multiple and degenerate ones)
and seeds — the CORE correctness signal for the compiled artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul_pallas, newton_schulz_pallas
from compile.kernels.matmul import matmul_ad, vmem_bytes
from compile.kernels.ref import (
    matmul_ref,
    newton_schulz_ref,
    orthogonalize_exact,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------

@given(
    m=st.integers(1, 200),
    k=st.integers(1, 200),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    y = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        matmul_pallas(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-3
    )


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384), (1, 1, 1),
                                   (127, 129, 130), (3, 500, 7)])
def test_matmul_key_shapes(shape):
    m, k, n = shape
    x, y = rand(0, (m, k)), rand(1, (k, n))
    np.testing.assert_allclose(
        matmul_pallas(x, y), matmul_ref(x, y), rtol=1e-4, atol=1e-3
    )


def test_matmul_bf16_inputs_accumulate_f32():
    x = rand(2, (64, 64), jnp.bfloat16)
    y = rand(3, (64, 64), jnp.bfloat16)
    out = matmul_pallas(x, y)
    assert out.dtype == jnp.float32
    ref = jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))
    np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-1)


def test_matmul_custom_tiles():
    x, y = rand(4, (96, 80)), rand(5, (80, 40))
    out = matmul_pallas(x, y, bm=32, bn=16, bk=64)
    np.testing.assert_allclose(out, matmul_ref(x, y), rtol=1e-4, atol=1e-3)


def test_matmul_grad_via_custom_vjp():
    x, y = rand(6, (16, 24)), rand(7, (24, 8))

    def f(x, y):
        return (matmul_ad(x, y) ** 2).sum()

    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    # analytic: d/dx ||xy||^2 = 2 (xy) y^T
    xy = x @ y
    np.testing.assert_allclose(gx, 2 * xy @ y.T, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(gy, 2 * x.T @ xy, rtol=1e-4, atol=1e-3)


def test_vmem_budget_documented():
    # the default tile schedule must fit a 16 MiB VMEM comfortably
    assert vmem_bytes() <= 16 * 2**20 / 4


# ---------------------------------------------------------------------------
# Newton–Schulz kernel
# ---------------------------------------------------------------------------

@given(
    m=st.integers(2, 96),
    n=st.integers(2, 96),
    seed=st.integers(0, 2**16),
)
def test_ns_matches_ref(m, n, seed):
    g = rand(seed, (m, n))
    np.testing.assert_allclose(
        newton_schulz_pallas(g), newton_schulz_ref(g), rtol=1e-3, atol=1e-3
    )


@pytest.mark.parametrize("shape", [(128, 384), (128, 128), (512, 128), (128, 512)])
def test_ns_artifact_shapes(shape):
    """The exact shapes aot.py compiles NS artifacts for."""
    g = rand(11, shape)
    out = newton_schulz_pallas(g)
    ref = newton_schulz_ref(g)
    np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-3)


def test_ns_singular_values_near_one():
    g = rand(13, (64, 48))
    o = newton_schulz_pallas(g)
    s = jnp.linalg.svd(o, compute_uv=False)
    assert float(s.min()) > 0.55 and float(s.max()) < 1.35


def test_ns_aligns_with_exact_polar():
    g = rand(17, (48, 64))
    o = np.asarray(newton_schulz_pallas(g))
    uvt = np.asarray(orthogonalize_exact(g))
    cos = (o * uvt).sum() / (np.linalg.norm(o) * np.linalg.norm(uvt))
    assert cos > 0.98, cos


def test_ns_zero_input_safe():
    out = newton_schulz_pallas(jnp.zeros((8, 8)))
    assert np.isfinite(np.asarray(out)).all()
