"""L2 model correctness: shapes, loss behaviour, gradient integrity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.PRESETS["nano"]


def toy_batch(cfg, batch=2, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (batch, cfg.seq_len), 0, cfg.vocab)
    tgts = jnp.roll(toks, -1, axis=1)
    return toks, tgts


def test_layer_table_param_count():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    table = M.layer_table(CFG)
    assert len(params) == len(table)
    for p, (name, shape, _) in zip(params, table):
        assert p.shape == shape, name
    assert CFG.param_count() == sum(int(np.prod(s)) for _, s, _ in table)


def test_groups_cover_expected_kinds():
    groups = {g for _, _, g in M.layer_table(CFG)}
    assert groups == {M.HIDDEN, M.EMBED, M.VECTOR}
    # hidden layers are exactly the 2-D matmul weights
    for name, shape, g in M.layer_table(CFG):
        if g == M.HIDDEN:
            assert len(shape) == 2 and min(shape) > 1, name


def test_forward_shapes_and_loss_at_init():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    toks, tgts = toy_batch(CFG)
    logits = M.forward(CFG, params, toks)
    assert logits.shape == (2, CFG.seq_len, CFG.vocab)
    loss = M.loss_fn(CFG, params, toks, tgts)
    # near-uniform at init: loss ≈ ln V
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.15


def test_causality():
    """Changing a future token must not change past logits."""
    params = M.init_params(CFG, jax.random.PRNGKey(1))
    toks, _ = toy_batch(CFG, batch=1, seed=2)
    logits = M.forward(CFG, params, toks)
    toks2 = toks.at[0, -1].set((toks[0, -1] + 1) % CFG.vocab)
    logits2 = M.forward(CFG, params, toks2)
    np.testing.assert_allclose(
        logits[0, :-1], logits2[0, :-1], rtol=1e-5, atol=1e-5
    )
    assert not np.allclose(logits[0, -1], logits2[0, -1])


def test_grad_fn_outputs():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    toks, tgts = toy_batch(CFG)
    out = M.grad_fn(CFG, params, toks, tgts)
    assert len(out) == len(params) + 1
    for g, p in zip(out[1:], params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_gradient_descends():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    toks, tgts = toy_batch(CFG, batch=4)
    out = M.grad_fn(CFG, params, toks, tgts)
    loss0, grads = out[0], out[1:]
    lr = 0.5
    stepped = [p - lr * g for p, g in zip(params, grads)]
    loss1 = M.loss_fn(CFG, stepped, toks, tgts)
    assert float(loss1) < float(loss0)


def test_grad_matches_finite_difference():
    params = M.init_params(CFG, jax.random.PRNGKey(3))
    toks, tgts = toy_batch(CFG, batch=1)
    out = M.grad_fn(CFG, params, toks, tgts)
    g_wte = np.asarray(out[1])
    # probe one touched embedding row
    row = int(toks[0, 0])
    eps = 1e-2
    for col in (0, 5):
        bump = params[0].at[row, col].add(eps)
        lp = M.loss_fn(CFG, [bump] + params[1:], toks, tgts)
        bump = params[0].at[row, col].add(-eps)
        lm = M.loss_fn(CFG, [bump] + params[1:], toks, tgts)
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert abs(fd - g_wte[row, col]) < 5e-3, (fd, g_wte[row, col])


@pytest.mark.parametrize("preset", sorted(M.PRESETS))
def test_presets_construct(preset):
    cfg = M.PRESETS[preset]
    assert cfg.d_model % cfg.n_head == 0
    assert cfg.param_count() > 0
