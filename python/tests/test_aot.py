"""AOT pipeline integrity: HLO text emission, manifest consistency, and the
init-params binary contract with the rust side."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from compile import model as M
from compile.aot import lower_ns, to_hlo_text

import jax
import jax.numpy as jnp


def test_hlo_text_emission_smoke():
    cfg = M.PRESETS["nano"]
    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    lowered = jax.jit(lambda x: (x @ x + 1.0,)).lower(spec)
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    del cfg


def test_ns_artifact_contains_pallas_lowering():
    text = lower_ns((16, 32), steps=2)
    assert "HloModule" in text
    # the tiled kernel lowers to dot ops inside while/fusion structures
    assert "dot(" in text or "dot " in text


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("aot_nano")
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--preset", "nano", "--batch",
         "2", "--out-dir", str(out)],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        capture_output=True,
        text=True,
    )
    assert r.returncode == 0, r.stderr
    return out


def test_manifest_matches_layer_table(built):
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    cfg = M.PRESETS["nano"]
    table = M.layer_table(cfg)
    assert len(manifest["layers"]) == len(table)
    for entry, (name, shape, group) in zip(manifest["layers"], table):
        assert entry["name"] == name
        assert tuple(entry["shape"]) == shape
        assert entry["group"] == group
    assert manifest["param_count"] == cfg.param_count()


def test_init_params_binary_roundtrip(built):
    cfg = M.PRESETS["nano"]
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    raw = np.fromfile(built / "init_params.bin", dtype="<f4")
    assert raw.size == manifest["param_count"]
    params = M.init_params(cfg, jax.random.PRNGKey(manifest["seed"]))
    flat = np.concatenate([np.asarray(p).reshape(-1) for p in params])
    np.testing.assert_array_equal(raw, flat.astype("<f4"))


def test_all_artifacts_exist(built):
    with open(built / "manifest.json") as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    for key in ("grad", "eval", "init_params"):
        assert (built / arts[key]).exists(), key
    for shape, path in arts["ns"].items():
        assert (built / path).exists(), shape


def test_grad_artifact_signature(built):
    """The HLO entry computation must take p params + tokens + targets and
    return 1 + p results (loss + per-layer grads)."""
    cfg = M.PRESETS["nano"]
    p = len(M.layer_table(cfg))
    text = (built / "grad.hlo.txt").read_text()
    lines = text.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    block = []
    for l in lines[start + 1:]:
        if l.strip() == "}":
            break
        block.append(l)
    n_params = sum(1 for l in block if " parameter(" in l and "= f32" in l)
    n_int_params = sum(1 for l in block if " parameter(" in l and "= s32" in l)
    assert n_params == p, f"{n_params} f32 params vs {p} layers"
    assert n_int_params == 2  # tokens + targets
    # ROOT tuple has loss + p grads
    root = next(l for l in block if "ROOT" in l)
    assert root.count("f32[") >= p + 1
