"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for rust (L3).

Run once at build time (``make artifacts``); the rust binary is then fully
self-contained. Interchange format is **HLO text**, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
xla_extension 0.5.1 (the version behind the published ``xla`` 0.1.6 crate)
rejects; the text parser reassigns ids and round-trips cleanly.

Emits, under --out-dir (default ../artifacts):
  grad.hlo.txt        (params..., tokens, targets) -> (loss, grads...)
  eval.hlo.txt        (params..., tokens, targets) -> (loss,)
  ns_{m}x{n}.hlo.txt  Newton-Schulz orthogonalization (Pallas matmul inside)
                      for every distinct hidden-layer shape
  manifest.json       layer table / shapes / groups / artifact index
  init_params.bin     f32 little-endian initial parameters (rust & jax agree)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.ns import newton_schulz_pallas, NS_STEPS
from .kernels.matmul import vmem_bytes, DEFAULT_BM, DEFAULT_BN, DEFAULT_BK


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: M.GptConfig, batch: int):
    """Lower grad + eval closures over fixed (batch, seq_len) shapes."""
    pspecs = [
        jax.ShapeDtypeStruct(shape, jnp.float32)
        for _, shape, _ in M.layer_table(cfg)
    ]
    tok = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)

    def grad_flat(*args):
        params, tokens, targets = list(args[:-2]), args[-2], args[-1]
        return M.grad_fn(cfg, params, tokens, targets)

    def eval_flat(*args):
        params, tokens, targets = list(args[:-2]), args[-2], args[-1]
        return M.eval_fn(cfg, params, tokens, targets)

    grad_l = jax.jit(grad_flat).lower(*pspecs, tok, tok)
    eval_l = jax.jit(eval_flat).lower(*pspecs, tok, tok)
    return to_hlo_text(grad_l), to_hlo_text(eval_l)


def lower_ns(shape, steps=NS_STEPS):
    spec = jax.ShapeDtypeStruct(shape, jnp.float32)
    fn = lambda g: (newton_schulz_pallas(g, steps=steps),)
    return to_hlo_text(jax.jit(fn).lower(spec))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", default="micro", choices=sorted(M.PRESETS))
    ap.add_argument("--batch", type=int, default=8,
                    help="per-worker microbatch baked into grad.hlo.txt")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=os.path.join(
        os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--skip-model", action="store_true",
                    help="only NS artifacts (fast dev loop)")
    args = ap.parse_args()

    cfg = M.PRESETS[args.preset]
    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)
    table = M.layer_table(cfg)

    def write(name, text):
        path = os.path.join(out, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"  wrote {name} ({len(text)} chars)")

    # --- init params (bit-exact contract with rust) ---
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    flat = np.concatenate([np.asarray(p, np.float32).reshape(-1) for p in params])
    flat.astype("<f4").tofile(os.path.join(out, "init_params.bin"))
    print(f"  wrote init_params.bin ({flat.size} f32 = {4*flat.size} bytes)")

    # --- NS artifacts for every distinct hidden shape ---
    hidden_shapes = sorted({shape for _, shape, g in table if g == M.HIDDEN})
    ns_index = {}
    for shape in hidden_shapes:
        name = f"ns_{shape[0]}x{shape[1]}.hlo.txt"
        write(name, lower_ns(shape))
        ns_index[f"{shape[0]}x{shape[1]}"] = name

    # --- model grad/eval artifacts ---
    if not args.skip_model:
        grad_txt, eval_txt = lower_model(cfg, args.batch)
        write("grad.hlo.txt", grad_txt)
        write("eval.hlo.txt", eval_txt)

    manifest = {
        "preset": args.preset,
        "config": {
            "vocab": cfg.vocab, "seq_len": cfg.seq_len,
            "d_model": cfg.d_model, "n_layer": cfg.n_layer,
            "n_head": cfg.n_head, "d_ff": cfg.d_ff,
        },
        "batch": args.batch,
        "seed": args.seed,
        "param_count": int(flat.size),
        "layers": [
            {"name": n, "shape": list(s), "group": g} for n, s, g in table
        ],
        "artifacts": {
            "grad": "grad.hlo.txt",
            "eval": "eval.hlo.txt",
            "init_params": "init_params.bin",
            "ns": ns_index,
        },
        "ns_steps": NS_STEPS,
        "arg_order": "params (layer-table order), tokens i32[B,T], targets i32[B,T]",
        "grad_outputs": "tuple(loss f32[], grad per layer in table order)",
        "l1_kernel": {
            "bm": DEFAULT_BM, "bn": DEFAULT_BN, "bk": DEFAULT_BK,
            "vmem_bytes": vmem_bytes(),
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(table)} layers, "
          f"{flat.size/1e6:.2f}M params)")


if __name__ == "__main__":
    main()
