"""L1 Pallas kernels for EF21-Muon.

All kernels are authored for TPU (BlockSpec-tiled, MXU-shaped blocks) but
lowered with ``interpret=True`` on this image so the resulting HLO runs on
any PJRT backend, including the rust CPU client. Correctness oracles live in
``ref.py`` and are enforced by ``python/tests``.
"""

from .matmul import matmul_pallas
from .ns import newton_schulz_pallas, NS_COEFFS, NS_STEPS

__all__ = ["matmul_pallas", "newton_schulz_pallas", "NS_COEFFS", "NS_STEPS"]
