"""Tiled matmul Pallas kernel — the compute hot spot of Muon's Newton–Schulz
orthogonalization (three dense contractions per NS step).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the GPU reference does
bf16 tensor-core matmuls; here the HBM↔VMEM schedule is expressed with
``BlockSpec`` over a (M/bm, N/bn, K/bk) grid. The K axis is the innermost
(sequential) grid dimension, so the f32 output tile stays resident in VMEM
and accumulates across K steps — the standard MXU-friendly pattern.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the same kernel to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped default tiles. Shapes that do not divide are handled by
# rounding the operands up with zero padding (zeros do not change the
# product) and slicing the result back down.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the sequential K axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # f32 accumulation on the MXU: preferred_element_type pins the
    # accumulator type regardless of input dtype (bf16-friendly).
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(x, m, n):
    pm = (-x.shape[0]) % m
    pn = (-x.shape[1]) % n
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_pallas(x, y, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK,
                  interpret=True):
    """``x @ y`` via the tiled Pallas kernel.

    Args:
      x: (m, k) array. y: (k, n) array.
      bm/bn/bk: VMEM tile sizes. VMEM footprint ≈ (bm*bk + bk*bn + bm*bn)*4B.
    Returns:
      (m, n) f32 array.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(x.astype(jnp.float32), bm_, bk_)
    yp = _pad_to(y.astype(jnp.float32), bk_, bn_)
    mp, kp = xp.shape
    _, np_ = yp.shape
    grid = (mp // bm_, np_ // bn_, kp // bk_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp, yp)
    return out[:m, :n]


def vmem_bytes(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK, dtype_bytes=4):
    """Estimated per-step VMEM residency of the kernel (DESIGN.md §Perf)."""
    return (bm * bk + bk * bn + bm * bn) * dtype_bytes


# ---------------------------------------------------------------------------
# Differentiable wrapper. pallas_call (interpret included) has no VJP rule,
# so the L2 model uses this custom_vjp: the backward pass is the textbook
# matmul VJP, itself routed through the same Pallas kernel — both directions
# of the training graph hit the L1 tile schedule.
# ---------------------------------------------------------------------------

@jax.custom_vjp
def matmul_ad(x, y):
    """Differentiable ``x @ y`` through the tiled Pallas kernel."""
    return matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    dx = matmul_pallas(g, y.T)
    dy = matmul_pallas(x.T, g)
    return dx.astype(x.dtype), dy.astype(y.dtype)


matmul_ad.defvjp(_matmul_fwd, _matmul_bwd)
