"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These define ground truth: every kernel must match its oracle to float32
tolerance across a hypothesis sweep of shapes (see python/tests).
"""

import jax.numpy as jnp

from .ns import NS_COEFFS, NS_STEPS


def matmul_ref(x, y):
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))


def axpby_ref(a, b, ca, cb):
    return ca * a + cb * b


def newton_schulz_ref(g, steps=NS_STEPS):
    """Reference NS iteration with plain jnp contractions."""
    a, b, c = NS_COEFFS
    m, n = g.shape
    transpose = m > n
    x = g.T if transpose else g
    x = x.astype(jnp.float32)
    x = x / (jnp.linalg.norm(x) + 1e-7)
    for _ in range(steps):
        gram = x @ x.T
        poly = b * gram + c * (gram @ gram)
        x = a * x + poly @ x
    return x.T if transpose else x


def orthogonalize_exact(g):
    """Exact UV^T via SVD — the object NS approximates."""
    u, _, vt = jnp.linalg.svd(g.astype(jnp.float32), full_matrices=False)
    return u @ vt
