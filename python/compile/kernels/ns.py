"""Newton–Schulz orthogonalization as Pallas kernels.

Muon's spectral-norm LMO is ``LMO(G) = -U V^T`` from the SVD of the momentum
matrix. Exact SVD is not accelerator-friendly; Muon approximates ``U V^T``
with a quintic Newton–Schulz iteration (Jordan et al. 2024; Kovarik 1970;
Björck & Bowie 1971):

    X0 = G / ||G||_F
    X_{t+1} = a X_t + (b A + c A^2) X_t,   A = X_t X_t^T

with (a, b, c) tuned so the polynomial's fixed point maps all singular
values to ~1. Three contractions per step — all routed through the tiled
Pallas matmul kernel — plus one fused element-wise polynomial-combine
Pallas kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import matmul_pallas

# Quintic coefficients from the Muon reference implementation.
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5


def _axpby_kernel(a_ref, b_ref, o_ref, *, ca, cb):
    """o = ca * a + cb * b, fused element-wise (one VMEM round-trip)."""
    o_ref[...] = ca * a_ref[...] + cb * b_ref[...]


def _axpby(a, b, ca, cb, *, interpret=True, block=128):
    m, n = a.shape
    bm, bn = min(block, m), min(block, n)
    # Pad to tile multiples; padding is sliced off afterwards.
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
        b = jnp.pad(b, ((0, pm), (0, pn)))
    grid = (a.shape[0] // bm, a.shape[1] // bn)
    out = pl.pallas_call(
        functools.partial(_axpby_kernel, ca=ca, cb=cb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def newton_schulz_pallas(g, *, steps=NS_STEPS, interpret=True):
    """Approximate ``U V^T`` of ``g`` (m×n, any aspect) via NS iteration.

    Tall matrices are transposed first so the Gram matrix ``X X^T`` is the
    small square — the same trick as the Muon reference.
    """
    a, b, c = NS_COEFFS
    m, n = g.shape
    transpose = m > n
    x = g.T if transpose else g
    x = x.astype(jnp.float32)
    x = x / (jnp.linalg.norm(x) + 1e-7)
    mm = lambda p, q: matmul_pallas(p, q, interpret=interpret)
    for _ in range(steps):
        gram = mm(x, x.T)                       # A  = X X^T  (k×k, k=min(m,n))
        gram2 = mm(gram, gram)                  # A^2
        poly = _axpby(gram, gram2, b, c, interpret=interpret)  # bA + cA^2
        x = _axpby(x, mm(poly, x), a, 1.0, interpret=interpret)
    return x.T if transpose else x
