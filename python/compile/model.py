"""L2 — MicroGPT: the paper's NanoGPT workload, scaled for this testbed.

Decoder-only transformer (GPT-2 style, as in Karpathy's nanoGPT which the
paper trains): learned token + position embeddings, pre-LayerNorm (gain
only, no bias — the modern nanoGPT default), causal self-attention, GELU
MLP, weight-tied output head.

Functional/stateless: parameters are a *flat list of arrays* in the fixed
order given by ``layer_table`` so the rust coordinator can address layer i
by index. Hidden 2-D matrices form the "hidden" group (spectral-norm LMO —
Muon); embeddings/head the "embed" group (ℓ∞ LMO — Scion's choice, which the
paper also uses); LayerNorm gains the "vector" group (ℓ∞ LMO).

The MLP matmuls are routed through the L1 Pallas kernel (``matmul_ad``) so
the Pallas tile schedule lowers into the grad artifact itself.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul_ad

# Parameter groups (mirrored by rust/src/model/mod.rs).
HIDDEN = "hidden"   # 2-D matmul weights -> spectral LMO (Muon)
EMBED = "embed"     # embedding / tied head -> sign (ℓ∞) LMO
VECTOR = "vector"   # LayerNorm gains -> sign LMO, tiny radii


@dataclasses.dataclass(frozen=True)
class GptConfig:
    vocab: int = 256        # byte-level, as our synthetic corpus is bytes
    seq_len: int = 128
    d_model: int = 128
    n_layer: int = 2
    n_head: int = 4
    d_ff: int = 512         # 4 * d_model by convention

    @property
    def head_dim(self):
        return self.d_model // self.n_head

    def param_count(self):
        return sum(int(math.prod(s)) for _, s, _ in layer_table(self))


def layer_table(cfg: GptConfig):
    """Fixed (name, shape, group) order — the contract with the rust side."""
    t = [
        ("wte", (cfg.vocab, cfg.d_model), EMBED),
        ("wpe", (cfg.seq_len, cfg.d_model), EMBED),
    ]
    for i in range(cfg.n_layer):
        t += [
            (f"h{i}.ln1_g", (cfg.d_model,), VECTOR),
            (f"h{i}.attn_qkv", (cfg.d_model, 3 * cfg.d_model), HIDDEN),
            (f"h{i}.attn_out", (cfg.d_model, cfg.d_model), HIDDEN),
            (f"h{i}.ln2_g", (cfg.d_model,), VECTOR),
            (f"h{i}.mlp_fc", (cfg.d_model, cfg.d_ff), HIDDEN),
            (f"h{i}.mlp_proj", (cfg.d_ff, cfg.d_model), HIDDEN),
        ]
    t.append(("lnf_g", (cfg.d_model,), VECTOR))
    return t


def init_params(cfg: GptConfig, key):
    """GPT-2 style init: N(0, 0.02) embeddings, scaled residual projections."""
    params = []
    resid_scale = 1.0 / math.sqrt(2 * cfg.n_layer)
    for name, shape, group in layer_table(cfg):
        key, sub = jax.random.split(key)
        if group == VECTOR:
            p = jnp.ones(shape, jnp.float32)
        elif group == EMBED:
            p = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            std = 0.02 * (resid_scale if name.endswith(("attn_out", "mlp_proj")) else 1.0)
            p = std * jax.random.normal(sub, shape, jnp.float32)
        params.append(p)
    return params


def _layernorm(x, g):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return g * (x - mu) * jax.lax.rsqrt(var + 1e-5)


def _attention(cfg, x, w_qkv, w_out):
    b, t, d = x.shape
    qkv = x @ w_qkv                                     # (B,T,3D)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    def heads(z):
        return z.reshape(b, t, cfg.n_head, cfg.head_dim).transpose(0, 2, 1, 3)
    q, k, v = heads(q), heads(k), heads(v)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((t, t), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    y = (att @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return y @ w_out


def _mlp(x, w_fc, w_proj):
    b, t, d = x.shape
    h = matmul_ad(x.reshape(b * t, d), w_fc)            # L1 Pallas kernel
    h = jax.nn.gelu(h)
    return matmul_ad(h, w_proj).reshape(b, t, d)


def forward(cfg: GptConfig, params, tokens):
    """tokens (B,T) int32 -> logits (B,T,V)."""
    it = iter(params)
    wte, wpe = next(it), next(it)
    b, t = tokens.shape
    x = wte[tokens] + wpe[:t][None, :, :]
    for _ in range(cfg.n_layer):
        ln1_g, w_qkv, w_out, ln2_g, w_fc, w_proj = (next(it) for _ in range(6))
        x = x + _attention(cfg, _layernorm(x, ln1_g), w_qkv, w_out)
        x = x + _mlp(_layernorm(x, ln2_g), w_fc, w_proj)
    lnf_g = next(it)
    x = _layernorm(x, lnf_g)
    return x @ wte.T                                    # tied head


def loss_fn(cfg: GptConfig, params, tokens, targets):
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def grad_fn(cfg: GptConfig, params, tokens, targets):
    """(loss, grads) — the object AOT-lowered into grad.hlo.txt."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, targets))(params)
    return (loss, *grads)


def eval_fn(cfg: GptConfig, params, tokens, targets):
    return (loss_fn(cfg, params, tokens, targets),)


# Named model presets exposed through aot.py / the rust config system.
PRESETS = {
    # end-to-end driver default: small enough for a 1-core CPU testbed
    "micro": GptConfig(vocab=256, seq_len=128, d_model=128, n_layer=2,
                       n_head=4, d_ff=512),
    # smoke/test preset
    "nano": GptConfig(vocab=256, seq_len=64, d_model=64, n_layer=2,
                      n_head=2, d_ff=256),
    # closer to the paper's nanoGPT-124M shape family (compile-only on CPU)
    "small": GptConfig(vocab=256, seq_len=256, d_model=384, n_layer=6,
                       n_head=6, d_ff=1536),
}
