//! The paper's motivating example (§2, §A.2; Beznosikov et al. 2020,
//! Example 1): distributed gradient descent with biased Top1 compression
//! and NO error feedback diverges *exponentially* on an average of three
//! strongly convex quadratics — while EF14 and EF21-Muon converge with the
//! very same compressor and stepsize.
//!
//! ```bash
//! cargo run --release --example divergence_demo
//! ```

fn main() -> anyhow::Result<()> {
    println!("f_j(x) = <a_j, x>^2 / 2,  a_1=(-3,2,2), a_2=(2,-3,2), a_3=(2,2,-3)");
    println!("x0 = (1,1,1); Top1 compression; stepsize 0.1\n");
    efmuon::exp::divergence::run_demo(60, &mut std::io::stdout())?;
    Ok(())
}
