//! Quickstart: distributed EF21-Muon in ~30 lines.
//!
//! Trains the AOT-compiled MicroGPT for a few steps with 4 workers and
//! RankK+Natural compression, printing the loss curve and the exact
//! communication savings. Build artifacts first: `make artifacts`.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use efmuon::config::TrainConfig;

fn main() -> anyhow::Result<()> {
    let cfg = TrainConfig {
        artifacts: "artifacts".into(),
        workers: 4,
        steps: 30,
        worker_comp: "rank:0.15+nat".into(), // the paper's 7x-savings config
        server_comp: "id".into(),            // paper setting; any spec (e.g.
                                             // "top:0.25") compresses s2w too
        beta: 0.9,
        lr: 0.02,
        warmup: 5,
        corpus_tokens: 500_000,
        eval_every: 5,
        eval_batches: 2,
        seed: 0,
        ..TrainConfig::default()
    };

    let report = efmuon::train::train(&cfg)?;

    println!("\n  step      tokens   eval loss");
    for p in &report.curve {
        println!("{:>6} {:>11} {:>11.4}", p.step, p.tokens_processed, p.eval_loss);
    }
    let per_step =
        report.total_w2s_bytes_per_worker as f64 / report.steps as f64 / report.model_bytes as f64;
    println!(
        "\nw2s traffic: {:.4}x model size per step (dense would be 1.0x) — {:.1}x saving",
        per_step,
        1.0 / per_step
    );
    Ok(())
}
