//! Tour of the compressor zoo (paper §D): for each operator, the measured
//! contraction ratio (Definition 1) in its declared norm family, the exact
//! wire size, and the decoded reconstruction error — on a MicroGPT-shaped
//! hidden layer.
//!
//! ```bash
//! cargo run --release --example compressor_zoo
//! ```

use efmuon::compress::{codec, contraction_ratio, parse_spec};
use efmuon::linalg::{norms, Matrix};
use efmuon::metrics::render_table;
use efmuon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);
    let x = Matrix::randn(128, 512, 1.0, &mut rng); // an mlp_fc-shaped layer
    let dense_bytes = x.numel() * 4;

    let specs = [
        "id",
        "damp:0.8",
        "drop:0.5",
        "nat",
        "top:0.2",
        "top:0.1",
        "top:0.1+nat",
        "rank:0.2",
        "rank:0.1",
        "rank:0.1+nat",
        "svdtop:4",
        "coltop:0.2",
    ];

    let mut rows = Vec::new();
    for spec in specs {
        let mut c = parse_spec(spec).map_err(anyhow::Error::msg)?;
        // average the (possibly randomized) contraction over a few draws
        let reps = 8;
        let mut ratio = 0.0;
        let mut bytes = 0usize;
        let mut last = None;
        for _ in 0..reps {
            let msg = c.compress(&x, &mut rng);
            bytes = msg.wire_bytes();
            let dec = msg.decode();
            ratio += contraction_ratio(&x, &dec) / reps as f64;
            last = Some((msg, dec));
        }
        let (msg, dec) = last.unwrap();
        // wire codec sanity: encode -> decode must reproduce the message
        let roundtrip = codec::decode(&codec::encode(&msg)).unwrap();
        assert_eq!(roundtrip, msg, "{spec}: codec roundtrip");
        rows.push(vec![
            spec.to_string(),
            format!("{:?}", c.family()),
            format!("{:.4}", 1.0 - ratio), // alpha estimate
            format!("{:.4}", bytes as f64 / dense_bytes as f64),
            format!("{:.3}", norms::fro(&dec.sub(&x)) / norms::fro(&x)),
        ]);
    }

    println!("layer: 128x512 f32 ({} bytes dense)\n", dense_bytes);
    println!(
        "{}",
        render_table(
            &["spec", "family", "alpha (measured)", "rel. wire cost", "rel. L2 err"],
            &rows
        )
    );
    println!("alpha = contraction parameter of Definition 1 (higher = more faithful)");
    println!("note how damp/drop satisfy the definition without saving bytes —");
    println!("the paper's point that contractivity != communication efficiency.");
    Ok(())
}
