//! **End-to-end driver** (DESIGN.md / EXPERIMENTS.md §E2E): pretrain the
//! AOT-compiled MicroGPT transformer on the synthetic Zipf–Markov corpus
//! for a few hundred steps with 4 workers, comparing compressed EF21-Muon
//! against the uncompressed Muon/Scion/Gluon baseline, and log both loss
//! curves + exact communication meters. All three layers compose here:
//! L1 Pallas kernels (inside grad.hlo.txt and the NS artifacts) → L2 JAX
//! model → L3 rust coordinator.
//!
//! ```bash
//! make artifacts && cargo run --release --example distributed_pretrain \
//!     [-- --steps 300 --comp rank:0.15+nat]
//! ```
//!
//! Results are appended to results/e2e_*.jsonl and summarized on stdout.

use efmuon::config::TrainConfig;
use efmuon::train::TrainReport;
use efmuon::util::cli::Args;

fn run(cfg: &TrainConfig, label: &str) -> anyhow::Result<TrainReport> {
    eprintln!("== {label}: {} ==", cfg.worker_comp);
    let report = efmuon::train::train(cfg)?;
    eprintln!(
        "   final eval loss {:.4} in {:.1}s ({:.2} s/step)",
        report.final_eval_loss,
        report.wall_seconds,
        report.wall_seconds / report.steps as f64
    );
    Ok(report)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize("steps", 300);
    let comp = args.str("comp", "rank:0.15+nat");
    let base = TrainConfig {
        artifacts: args.str("artifacts", "artifacts"),
        workers: args.usize("workers", 4),
        steps,
        beta: 0.9,
        lr: args.f64("lr", 0.02),
        warmup: steps / 20 + 1,
        corpus_tokens: 2_000_000,
        eval_every: (steps / 20).max(1),
        eval_batches: 4,
        seed: args.u64("seed", 0),
        ..TrainConfig::default()
    };

    std::fs::create_dir_all("results")?;

    // uncompressed baseline = Muon/Scion/Gluon (identity compressors)
    let mut cfg_id = base.clone();
    cfg_id.worker_comp = "id".into();
    cfg_id.log_path = Some("results/e2e_id.jsonl".into());
    let id = run(&cfg_id, "baseline (uncompressed Gluon)")?;

    // compressed EF21-Muon
    let mut cfg_c = base.clone();
    cfg_c.worker_comp = comp.clone();
    cfg_c.log_path = Some("results/e2e_compressed.jsonl".into());
    let cmp = run(&cfg_c, "EF21-Muon")?;

    // ---- summary ----
    println!("\n==================== E2E SUMMARY ====================");
    println!("model bytes: {}  tokens/step: {}", id.model_bytes, id.tokens_per_step);
    println!("\n{:<10} {:>14} {:>14}", "step", "id eval", format!("{comp} eval"));
    for (a, b) in id.curve.iter().zip(&cmp.curve) {
        println!("{:<10} {:>14.4} {:>14.4}", a.step, a.eval_loss, b.eval_loss);
    }
    let id_rel = id.total_w2s_bytes_per_worker as f64 / id.model_bytes as f64;
    let cmp_rel = cmp.total_w2s_bytes_per_worker as f64 / cmp.model_bytes as f64;
    println!("\nw2s bytes/worker over the run (in model sizes):");
    println!("  id:   {id_rel:.2}");
    println!("  {comp}: {cmp_rel:.2}   ({:.1}x less traffic)", id_rel / cmp_rel);
    let target = id.final_eval_loss.max(cmp.final_eval_loss) * 1.01;
    if let (Some(bi), Some(bc)) =
        (id.relative_bytes_to_loss(target), cmp.relative_bytes_to_loss(target))
    {
        println!(
            "\nbytes to reach eval loss {target:.4}: id {bi:.2} vs {comp} {bc:.2} \
             => {:.1}x communication saving",
            bi / bc
        );
    }
    println!("\nloss delta at end: {:+.4} (compression cost in accuracy)",
             cmp.final_eval_loss - id.final_eval_loss);
    println!("curves logged to results/e2e_id.jsonl / results/e2e_compressed.jsonl");
    Ok(())
}
