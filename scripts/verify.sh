#!/usr/bin/env bash
# Tier-1 verification + hotpath perf smoke (see DESIGN.md §Verification).
#
#   scripts/verify.sh            # build + tests + hotpath bench (5 iters)
#   scripts/verify.sh --no-bench # tier-1 only
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
  echo "== perf smoke: hotpath bench (--iters 5) =="
  cargo bench --bench hotpath -- --iters 5
  echo "== BENCH_hotpath.json =="
  cat ../BENCH_hotpath.json 2>/dev/null || cat BENCH_hotpath.json
  echo
fi

echo "verify: OK"
