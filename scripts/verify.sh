#!/usr/bin/env bash
# Tier-1 verification + tier-2 scenario/perf gates (DESIGN.md §Verification).
#
#   scripts/verify.sh            # tier-1 + scenario harness + hotpath bench
#                                # + round-time regression gate
#   scripts/verify.sh --no-bench # tier-1 only
#
# The perf gate compares the hotpath round times against BENCH_baseline.json
# at the repo root (self-priming: first run on a machine creates it) and
# fails on a >5% median regression. EFMUON_BENCH_TOLERANCE overrides the
# 1.05 threshold.
set -euo pipefail

SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

echo "== tier-1: bench-gate unit tests (python) =="
# the gate script is part of the verification surface: its trajectory /
# traced-pair / bf16 logic is unit-tested so a broken gate cannot silently
# pass (or fail) every bench run
python3 "$SCRIPT_DIR/test_bench_gate.py"

echo "== smoke: typed config round trip (efmuon config) =="
# `efmuon config` prints the validated RunSpec as canonical JSON; feeding
# that JSON back through --config must reproduce it byte for byte — the
# lossless RunSpec -> Json -> RunSpec contract of the spec layer.
EFMUON=target/release/efmuon
CFG_TMP="$(mktemp)"
trap 'rm -f "$CFG_TMP" "$CFG_TMP.2"' EXIT
"$EFMUON" config > "$CFG_TMP"
"$EFMUON" config --config "$CFG_TMP" > "$CFG_TMP.2"
diff "$CFG_TMP" "$CFG_TMP.2"
# presets must validate and round-trip too
for preset in muon scion gluon ef21-muon ef21-p; do
  "$EFMUON" config --preset "$preset" > "$CFG_TMP"
  "$EFMUON" config --config "$CFG_TMP" > "$CFG_TMP.2"
  diff "$CFG_TMP" "$CFG_TMP.2"
done
echo "config round trip: OK"

if [[ "${1:-}" != "--no-bench" ]]; then
  # tier-1 already ran scenario.rs in debug; the release rerun is deliberate:
  # it shares the release build with the bench below (no extra codegen of the
  # library) and exercises the timing-sensitive pipeline at release speed
  echo "== tier-2: scenario harness (release) =="
  cargo test --release -q --test scenario

  # the fault-injection subset reruns by name so a timing-sensitive failure
  # (deadline/quorum/respawn under release scheduling) is attributed to the
  # fault layer in the verify log rather than buried in the full harness
  echo "== tier-2: fault-injection scenarios (release) =="
  cargo test --release -q --test scenario fault

  # the socket-transport subset reruns by name for the same reason: the
  # loopback ≡ channel golden and the flaky-link chaos run (reconnect,
  # heartbeat, elastic membership) are timing-sensitive under release
  # scheduling, and a failure here should name the transport layer
  echo "== tier-2: loopback-socket scenarios (release) =="
  cargo test --release -q --test scenario net_

  # the shard-scheduler subset reruns by name too: the bounded-epoch window
  # and the steal migration are the most timing-sensitive paths in the repo
  # (EWMA round-time sampling, injected shard stalls, out-of-order epoch
  # seals), and their bitwise goldens must hold under release scheduling
  echo "== tier-2: shard-scheduler scenarios (release) =="
  cargo test --release -q --test scenario sched_

  # the microkernel's bit-identity contract and the non-finite propagation
  # policy rerun by name in release: optimized codegen (vectorization, FMA
  # contraction if it ever crept in) is exactly what could break bitwise
  # agreement with the scalar reference, so the debug-mode pass isn't enough
  echo "== tier-2: microkernel bit-identity (release) =="
  cargo test --release -q --lib blocked_bitwise_equals_reference
  cargo test --release -q --lib nonfinite_inputs_match_reference_bitwise
  cargo test --release -q --lib matmul_at_propagates_nonfinite

  echo "== tier-2: non-finite propagation suite (release) =="
  cargo test --release -q --test nonfinite

  # the bf16 parameter-board golden: bf16-off must stay bit-identical to
  # f32 while shipping exactly half the board bytes
  echo "== tier-2: bf16 board golden (release) =="
  cargo test --release -q --test cluster \
    bf16_board_halves_snapshot_traffic_and_keeps_separable_trajectories

  echo "== perf smoke: hotpath bench (--iters 5) =="
  cargo bench --bench hotpath -- --iters 5
  BENCH=../BENCH_hotpath.json
  [[ -f "$BENCH" ]] || BENCH=BENCH_hotpath.json
  echo "== $BENCH =="
  cat "$BENCH"
  echo

  echo "== tier-2: round-time + bytes + GFLOP/s regression gate =="
  # gates cluster-round host memory traffic (bytes_cloned_per_round) along
  # with median round times, the matmul microkernel GFLOP/s (throughput
  # regression >5% fails), the bf16 board's wire bytes (each bf16 row must
  # ship <= 0.55x its matched f32 row), the traced round's overhead (must
  # stay within the threshold of its untraced twin), and — via --results —
  # the trajectory: round times must stay within the threshold of the
  # best-ever run in the appended experiment history
  python3 "$SCRIPT_DIR/bench_gate.py" "$BENCH" "$SCRIPT_DIR/../BENCH_baseline.json" \
    --threshold "${EFMUON_BENCH_TOLERANCE:-1.05}" \
    --results "$SCRIPT_DIR/../results/results.jsonl"
fi

echo "verify: OK"
