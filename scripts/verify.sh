#!/usr/bin/env bash
# Tier-1 verification + tier-2 scenario/perf gates (DESIGN.md §Verification).
#
#   scripts/verify.sh            # tier-1 + scenario harness + hotpath bench
#                                # + round-time regression gate
#   scripts/verify.sh --no-bench # tier-1 only
#
# The perf gate compares the hotpath round times against BENCH_baseline.json
# at the repo root (self-priming: first run on a machine creates it) and
# fails on a >5% median regression. EFMUON_BENCH_TOLERANCE overrides the
# 1.05 threshold.
set -euo pipefail

SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" != "--no-bench" ]]; then
  # tier-1 already ran scenario.rs in debug; the release rerun is deliberate:
  # it shares the release build with the bench below (no extra codegen of the
  # library) and exercises the timing-sensitive pipeline at release speed
  echo "== tier-2: scenario harness (release) =="
  cargo test --release -q --test scenario

  echo "== perf smoke: hotpath bench (--iters 5) =="
  cargo bench --bench hotpath -- --iters 5
  BENCH=../BENCH_hotpath.json
  [[ -f "$BENCH" ]] || BENCH=BENCH_hotpath.json
  echo "== $BENCH =="
  cat "$BENCH"
  echo

  echo "== tier-2: round-time + bytes-cloned regression gate =="
  # gates cluster-round host memory traffic (bytes_cloned_per_round) along
  # with median round times: the zero-copy gradient path must stay zero-copy
  python3 "$SCRIPT_DIR/bench_gate.py" "$BENCH" "$SCRIPT_DIR/../BENCH_baseline.json" \
    --threshold "${EFMUON_BENCH_TOLERANCE:-1.05}"
fi

echo "verify: OK"
