#!/usr/bin/env python3
"""Unit tests for bench_gate.py's pure gate logic (no cargo, no bench run).

Covers the trajectory gate (best-ever selection, adoption of entries with
no history, malformed-record errors), the traced-pair overhead gate, and
the bf16 pairing gate — the pieces whose failure modes are subtle enough
to deserve synthetic regression cases. Run directly or via verify.sh:

    python3 scripts/test_bench_gate.py
"""

import os
import sys
import tempfile
import unittest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
import bench_gate  # noqa: E402


def entry(name, median_s, **extra):
    e = {"name": name, "median_s": median_s}
    e.update(extra)
    return e


def record(experiment="hotpath", timings=()):
    return {"experiment": experiment, "commit": "abc", "timings": list(timings)}


def timing(name, median_s):
    return {"name": name, "median_s": median_s, "iters": 5}


class BestEverTest(unittest.TestCase):
    def test_selects_minimum_across_history(self):
        records = [
            record(timings=[timing("coordinator round", 0.012)]),
            record(timings=[timing("coordinator round", 0.009)]),
            record(timings=[timing("coordinator round", 0.011)]),
        ]
        self.assertEqual(bench_gate.best_ever(records, "coordinator round"), 0.009)

    def test_unknown_name_and_junk_values_yield_none(self):
        records = [
            record(timings=[timing("other", 0.01)]),
            record(timings=[{"name": "coordinator round"}]),  # no median_s
            record(timings=[{"name": "coordinator round", "median_s": "fast"}]),
            record(timings=[{"name": "coordinator round", "median_s": 0}]),
            {"experiment": "x", "commit": "abc"},  # legacy record, no timings
        ]
        self.assertIsNone(bench_gate.best_ever(records, "coordinator round"))

    def test_ignores_other_experiments_timings_only_by_name(self):
        # best_ever keys on the timing name, which the bench keeps unique;
        # a same-named timing in another experiment record still counts
        # (the store is one history, the name is the identity)
        records = [
            record("hotpath", [timing("cluster round (2 shard(s))", 0.02)]),
            record("shards", [timing("cluster round (2 shard(s))", 0.015)]),
        ]
        self.assertEqual(
            bench_gate.best_ever(records, "cluster round (2 shard(s))"), 0.015
        )


class TrajectoryGateTest(unittest.TestCase):
    def test_synthetic_regression_fails_against_best_ever(self):
        # history: 10ms then 9ms; current run-over-run baseline would hold
        # 10.3ms vs 10ms (1.03x, passes), but best-ever 9ms makes it 1.144x
        records = [
            record(timings=[timing("coordinator round", 0.010)]),
            record(timings=[timing("coordinator round", 0.009)]),
        ]
        current = {"coordinator round": entry("coordinator round", 0.0103)}
        problems = bench_gate.trajectory_problems(current, records, 1.05)
        self.assertEqual(len(problems), 1)
        self.assertIn("best-ever", problems[0])
        self.assertIn("0.009000", problems[0])

    def test_within_threshold_passes(self):
        records = [record(timings=[timing("coordinator round", 0.010)])]
        current = {"coordinator round": entry("coordinator round", 0.0104)}
        self.assertEqual(
            bench_gate.trajectory_problems(current, records, 1.05), []
        )

    def test_new_entry_with_no_history_is_adopted_silently(self):
        # a round entry the store has never seen passes: its first appended
        # run becomes the trajectory later runs are gated against
        records = [record(timings=[timing("coordinator round", 0.010)])]
        current = {
            "coordinator round": entry("coordinator round", 0.010),
            "cluster round (new)": entry("cluster round (new)", 99.0),
        }
        self.assertEqual(
            bench_gate.trajectory_problems(current, records, 1.05), []
        )

    def test_non_gated_and_microkernel_entries_are_ignored(self):
        records = [
            record(timings=[timing("compress top:0.1", 0.001)]),
            record(timings=[timing("matmul 256 microkernel (1 thread)", 0.001)]),
        ]
        current = {
            "compress top:0.1": entry("compress top:0.1", 1.0),
            "matmul 256 microkernel (1 thread)": entry(
                "matmul 256 microkernel (1 thread)", 1.0
            ),
        }
        self.assertEqual(
            bench_gate.trajectory_problems(current, records, 1.05), []
        )


class LoadResultsTest(unittest.TestCase):
    def _write(self, text):
        f = tempfile.NamedTemporaryFile(
            "w", suffix=".jsonl", delete=False, dir=tempfile.gettempdir()
        )
        f.write(text)
        f.close()
        self.addCleanup(os.unlink, f.name)
        return f.name

    def test_loads_records_and_skips_blank_lines(self):
        path = self._write(
            '{"experiment":"hotpath","commit":"a","timings":[]}\n'
            "\n"
            '{"experiment":"shards","commit":"b"}\n'
        )
        records = bench_gate.load_results(path)
        self.assertEqual([r["experiment"] for r in records], ["hotpath", "shards"])

    def test_malformed_json_names_the_line(self):
        path = self._write('{"experiment":"a","commit":"c"}\nnot json\n')
        with self.assertRaises(ValueError) as ctx:
            bench_gate.load_results(path)
        self.assertIn(":2:", str(ctx.exception))

    def test_record_without_experiment_key_names_the_line(self):
        path = self._write('{"commit":"c"}\n')
        with self.assertRaises(ValueError) as ctx:
            bench_gate.load_results(path)
        err = str(ctx.exception)
        self.assertIn(":1:", err)
        self.assertIn("experiment", err)


class TraceGateTest(unittest.TestCase):
    def test_overhead_within_threshold_passes(self):
        entries = {
            "coordinator round": entry("coordinator round", 0.0100),
            "coordinator round, traced": entry("coordinator round, traced", 0.0103),
        }
        self.assertEqual(bench_gate.trace_problems(entries, 1.05), [])

    def test_overhead_past_threshold_fails(self):
        entries = {
            "coordinator round": entry("coordinator round", 0.0100),
            "coordinator round, traced": entry("coordinator round, traced", 0.0110),
        }
        problems = bench_gate.trace_problems(entries, 1.05)
        self.assertEqual(len(problems), 1)
        self.assertIn("1.100x", problems[0])

    def test_missing_untraced_mate_fails(self):
        entries = {
            "coordinator round, traced": entry("coordinator round, traced", 0.01),
        }
        problems = bench_gate.trace_problems(entries, 1.05)
        self.assertEqual(len(problems), 1)
        self.assertIn("no untraced mate", problems[0])


class FaultGateTest(unittest.TestCase):
    def test_zero_or_missing_counters_pass(self):
        # non-net round entries carry no reconnect counters at all; the
        # loopback entry carries them at zero — both are clean
        entries = {
            "coordinator round": entry(
                "coordinator round", 0.01, stragglers=0, respawns=0
            ),
            "coordinator round over loopback tcp": entry(
                "coordinator round over loopback tcp",
                0.01,
                stragglers=0,
                respawns=0,
                reconnects=0,
                heartbeat_misses=0,
            ),
        }
        self.assertEqual(bench_gate.fault_problems(entries), [])

    def test_nonzero_transport_counters_fail(self):
        entries = {
            "coordinator round over loopback tcp": entry(
                "coordinator round over loopback tcp",
                0.01,
                reconnects=1,
                heartbeat_misses=2,
            ),
        }
        problems = bench_gate.fault_problems(entries)
        self.assertEqual(len(problems), 2)
        self.assertTrue(any("reconnects=1" in p for p in problems))
        self.assertTrue(any("heartbeat_misses=2" in p for p in problems))

    def test_non_round_entries_are_not_gated(self):
        entries = {
            "compress top:0.1": entry("compress top:0.1", 0.001, reconnects=7),
        }
        self.assertEqual(bench_gate.fault_problems(entries), [])


class Bf16GateTest(unittest.TestCase):
    def test_halved_bytes_pass_and_unhalved_fail(self):
        entries = {
            "cluster round (2 shard(s))": entry(
                "cluster round (2 shard(s))", 0.01, snap_bytes_shipped_per_round=1000
            ),
            "cluster round (2 shard(s)), bf16 board": entry(
                "cluster round (2 shard(s)), bf16 board",
                0.01,
                snap_bytes_shipped_per_round=520,
            ),
        }
        self.assertEqual(bench_gate.bf16_problems(entries), [])
        entries["cluster round (2 shard(s)), bf16 board"][
            "snap_bytes_shipped_per_round"
        ] = 900
        self.assertEqual(len(bench_gate.bf16_problems(entries)), 1)


class SchedGateTest(unittest.TestCase):
    def test_zero_or_missing_counters_pass(self):
        entries = {
            "coordinator round": entry("coordinator round", 0.01),
            "cluster round (2 shard(s))": entry(
                "cluster round (2 shard(s))", 0.01, steals=0, epochs_ahead_max=0
            ),
        }
        self.assertEqual(bench_gate.sched_problems(entries), [])

    def test_nonzero_counters_in_balanced_entry_fail(self):
        entries = {
            "cluster round (4 shard(s))": entry(
                "cluster round (4 shard(s))", 0.01, steals=1, epochs_ahead_max=2
            ),
        }
        problems = bench_gate.sched_problems(entries)
        self.assertEqual(len(problems), 2)
        self.assertTrue(any("steals=1" in p for p in problems))
        self.assertTrue(any("epochs_ahead_max=2" in p for p in problems))

    def test_imbalanced_entries_are_exempt(self):
        entries = {
            "cluster round (4 shards, imbalanced, window:1)": entry(
                "cluster round (4 shards, imbalanced, window:1)",
                0.01,
                steals=0,
                epochs_ahead_max=1,
            ),
        }
        self.assertEqual(bench_gate.sched_problems(entries), [])

    def test_non_round_entries_are_not_gated(self):
        entries = {
            "compress top:0.1": entry("compress top:0.1", 0.001, steals=3),
        }
        self.assertEqual(bench_gate.sched_problems(entries), [])


class ImbalanceGateTest(unittest.TestCase):
    WIN = "cluster round (4 shards, imbalanced, window:1)"
    LOCK = "cluster round (4 shards, imbalanced, lock-step)"

    def test_windowed_strictly_below_lockstep_passes(self):
        entries = {
            self.LOCK: entry(self.LOCK, 0.0150),
            self.WIN: entry(self.WIN, 0.0090),
        }
        self.assertEqual(bench_gate.imbalance_problems(entries), [])

    def test_windowed_at_or_above_lockstep_fails(self):
        entries = {
            self.LOCK: entry(self.LOCK, 0.0150),
            self.WIN: entry(self.WIN, 0.0150),
        }
        problems = bench_gate.imbalance_problems(entries)
        self.assertEqual(len(problems), 1)
        self.assertIn(">= 1x", problems[0])

    def test_missing_lockstep_mate_fails(self):
        entries = {self.WIN: entry(self.WIN, 0.009)}
        problems = bench_gate.imbalance_problems(entries)
        self.assertEqual(len(problems), 1)
        self.assertIn("no lock-step mate", problems[0])

    def test_balanced_entries_are_not_paired(self):
        entries = {
            "cluster round (2 shard(s))": entry("cluster round (2 shard(s))", 0.01),
        }
        self.assertEqual(bench_gate.imbalance_problems(entries), [])


if __name__ == "__main__":
    unittest.main(verbosity=2)
