#!/usr/bin/env python3
"""Tier-2 perf gate for the hotpath bench (see DESIGN.md §Verification).

Compares the round-time entries of a fresh BENCH_hotpath.json against a
stored baseline and fails (exit 1) when any matched entry's median time
regressed past the threshold (default 1.05 = +5%, the ISSUE-2 bar).

Round entries that carry host memory-traffic counters
(`bytes_cloned_per_round`: bytes the cluster gradient path deep-copies per
round) are gated on those too, with the same threshold: a bytes_cloned
regression means the zero-copy snapshot path started cloning again —
deterministic, so any growth past the threshold (including any growth from
an exact-zero baseline) fails.

More gates ride along:

- The single-thread matmul `microkernel` entries gate on their GFLOP/s
  (throughput, so the regression direction is inverted: dropping below
  baseline/threshold fails).
- The `bf16 board` cluster entries must ship at most 0.55x the
  parameter-board bytes of their matched f32 entries — checked within the
  current results alone (the byte ratio is deterministic; no baseline).
- The `, traced` round entries must run within the threshold of their
  untraced mates — also within the current results alone, isolating the
  tracer overhead from machine noise.
- Scheduler counters (`steals`, `epochs_ahead_max`) must be exactly zero
  in every balanced round entry (balanced benches run lock-step); the
  `imbalanced` entries are exempt, and their windowed run must instead
  come in strictly below its lock-step mate's median — the bounded-epoch
  window's wall-clock acceptance.
- With `--results results/results.jsonl`, round entries additionally gate
  against the best-ever stored median over the whole experiment history
  (trajectory mode), so slow-boil regressions that pass every run-over-run
  comparison still fail.

Bench numbers are machine-specific, so the baseline is self-priming and
untracked: the first run on a machine copies the current results into the
baseline file (established from the PR-1-era bench set); later runs gate
against it. Delete the baseline to re-prime after an intentional change.

Usage: bench_gate.py CURRENT BASELINE [--threshold 1.05]
                     [--results results/results.jsonl]
"""

import argparse
import json
import os
import shutil
import sys

# the end-to-end round entries gate on median time; the matmul microkernel
# entries gate on GFLOP/s. Other kernel microbenches are tracked but too
# noisy at --iters 5 to fail a verify run on.
GATED_SUBSTRINGS = ("round", "microkernel")

# the hotpath bench always runs with fault injection off and over healthy
# links, so these counters must be exactly zero in every round entry —
# checked against the current results alone, no baseline needed.
# reconnects/heartbeat_misses nonzero in a fault-free loopback bench means
# the socket transport is dropping or stalling frames on a clean localhost
# link — a transport bug, never machine noise.
FAULT_KEYS = ("stragglers", "respawns", "reconnects", "heartbeat_misses")

# bf16 parameter-board entries pair with the f32 entry of the same name
# minus this tag; their per-round board bytes must be <= 0.55x the mate's
BF16_TAG = ", bf16 board"
BF16_BYTES_KEY = "snap_bytes_shipped_per_round"
BF16_MAX_RATIO = 0.55

# traced round entries pair with the untraced entry of the same name minus
# this tag; stamping + per-round ring drain must stay within the gate
# threshold of the untraced round time (the tracer-overhead acceptance)
TRACE_TAG = ", traced"

# scheduler counters: a balanced fault-free bench runs lock-step, so a
# nonzero steal or ahead-of-frontier high-water mark there means the
# bounded-epoch scheduler activated where it must be inert. The entries
# whose names carry IMBALANCED_MARK are exempt — running ahead of the
# stalled shard is their entire point.
SCHED_KEYS = ("steals", "epochs_ahead_max")
IMBALANCED_MARK = "imbalanced"

# the imbalanced scheduler entries pair a windowed run with a lock-step run
# of the same stalled deployment; the windowed median must come in strictly
# below its lock-step mate (the bounded-epoch window's acceptance bar)
WINDOW_TAG = ", window:1"
LOCKSTEP_TAG = ", lock-step"


def bf16_problems(entries):
    """Every bf16-board entry must ship at most BF16_MAX_RATIO of its
    matched f32 entry's parameter-board bytes. The counters are exact
    (width x params x rounds, no timing noise), so this is checked on the
    current results alone: a missing mate, a missing counter, or a ratio
    above the bound all fail the gate."""
    problems = []
    for name, e in sorted(entries.items()):
        if BF16_TAG not in name:
            continue
        mate = name.replace(BF16_TAG, "")
        if mate not in entries:
            problems.append(f"bf16 entry {name!r} has no matched f32 entry {mate!r}")
            continue
        cur = e.get(BF16_BYTES_KEY)
        base = entries[mate].get(BF16_BYTES_KEY)
        if cur is None or base is None:
            problems.append(
                f"bf16 pair {name!r} / {mate!r} is missing {BF16_BYTES_KEY}"
            )
            continue
        if base <= 0:
            problems.append(
                f"f32 entry {mate!r} ships 0 board bytes (nothing for bf16 to halve)"
            )
            continue
        if cur > BF16_MAX_RATIO * base:
            problems.append(
                f"bf16 entry {name!r} ships {cur}B vs f32 {base}B "
                f"({cur / base:.3f}x > {BF16_MAX_RATIO}x)"
            )
    return problems


def trace_problems(entries, threshold):
    """Every traced round entry must run within `threshold`x its untraced
    mate in the same results file. Like the bf16 gate this needs no
    baseline: both twins are measured by the same run on the same machine,
    so the ratio isolates the tracer overhead from machine noise."""
    problems = []
    for name, e in sorted(entries.items()):
        if TRACE_TAG not in name:
            continue
        mate = name.replace(TRACE_TAG, "")
        if mate not in entries:
            problems.append(f"traced entry {name!r} has no untraced mate {mate!r}")
            continue
        cur = e["median_s"]
        base = entries[mate]["median_s"]
        if base <= 0:
            problems.append(f"untraced mate {mate!r} has nonpositive median_s")
            continue
        if cur > threshold * base:
            problems.append(
                f"traced entry {name!r} took {cur:.6f}s vs untraced {base:.6f}s "
                f"({cur / base:.3f}x > {threshold}x)"
            )
    return problems


def load_results(path):
    """Parse the append-only experiment store (results/results.jsonl, one
    JSON record per line). Raises ValueError naming the offending line for
    malformed records — the store is history evidence; silently skipping a
    line could hide the best-ever entry a regression should gate against."""
    records = []
    with open(path) as f:
        for i, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError as e:
                raise ValueError(f"{path}:{i}: {e}")
            if not isinstance(rec, dict) or "experiment" not in rec:
                raise ValueError(f"{path}:{i}: record is missing 'experiment'")
            records.append(rec)
    return records


def best_ever(records, name):
    """Best (minimum) stored median_s for timing `name` over the whole
    history, or None when the history has never seen that timing. The
    current run is normally already appended when the gate runs; including
    it is harmless (min <= current, so it can only make the gate exact)."""
    vals = [
        t["median_s"]
        for r in records
        for t in r.get("timings", [])
        if isinstance(t, dict)
        and t.get("name") == name
        and isinstance(t.get("median_s"), (int, float))
        and t["median_s"] > 0
    ]
    return min(vals) if vals else None


def trajectory_problems(entries, records, threshold):
    """Trend gate: every gated round entry must stay within `threshold`x of
    its best-ever stored median, not merely the previous run's. This stops
    slow-boil regressions — a sequence of +4% steps that each pass the
    run-over-run gate but compound into a 2x loss. Entries with no stored
    history pass (their first appended run becomes the trajectory to beat)."""
    problems = []
    for name, e in sorted(entries.items()):
        if not any(s in name for s in GATED_SUBSTRINGS):
            continue
        if "microkernel" in name:
            continue  # throughput-gated; the store keeps timings only
        best = best_ever(records, name)
        if best is None:
            continue
        cur = e["median_s"]
        if cur > threshold * best:
            problems.append(
                f"round entry {name!r} took {cur:.6f}s vs best-ever "
                f"{best:.6f}s ({cur / best:.3f}x > {threshold}x)"
            )
    return problems


def fault_problems(entries):
    """Nonzero fault counters in a fault-free bench run fail the gate: a
    straggler or respawn inside a benchmark means either the fault layer
    fired spuriously or a worker genuinely stalled past a deadline — both
    are bugs, and both would silently skew the round-time medians."""
    problems = []
    for name, e in sorted(entries.items()):
        if not any(s in name for s in GATED_SUBSTRINGS):
            continue
        for key in FAULT_KEYS:
            v = e.get(key, 0)
            if v:
                problems.append(
                    f"round entry {name!r} has {key}={v} in a fault-free bench run"
                )
    return problems


def sched_problems(entries):
    """Nonzero scheduler counters in a balanced bench entry fail the gate:
    every non-imbalanced entry runs lock-step (or with an inert window), so
    a steal or a shard running ahead there means the scheduler fired where
    it must be a no-op — a determinism bug, never machine noise."""
    problems = []
    for name, e in sorted(entries.items()):
        if not any(s in name for s in GATED_SUBSTRINGS):
            continue
        if IMBALANCED_MARK in name:
            continue
        for key in SCHED_KEYS:
            v = e.get(key, 0)
            if v:
                problems.append(
                    f"round entry {name!r} has {key}={v} in a balanced lock-step bench"
                )
    return problems


def imbalance_problems(entries):
    """Every imbalanced windowed entry must beat its lock-step mate in the
    same results file — strictly, not within a threshold: the rotating
    stall dominates the round time, so a windowed run that fails to
    overlap it has lost the scheduler's entire wall-clock win. Like the
    bf16 and trace gates this needs no baseline (both twins are measured
    by the same run on the same machine)."""
    problems = []
    for name, e in sorted(entries.items()):
        if IMBALANCED_MARK not in name or WINDOW_TAG not in name:
            continue
        mate = name.replace(WINDOW_TAG, LOCKSTEP_TAG)
        if mate not in entries:
            problems.append(f"windowed entry {name!r} has no lock-step mate {mate!r}")
            continue
        cur = e["median_s"]
        base = entries[mate]["median_s"]
        if base <= 0:
            problems.append(f"lock-step mate {mate!r} has nonpositive median_s")
            continue
        if cur >= base:
            problems.append(
                f"windowed entry {name!r} took {cur:.6f}s vs lock-step "
                f"{base:.6f}s ({cur / base:.3f}x >= 1x)"
            )
    return problems


def load_entries(path):
    """Index a bench file's entries by name.

    Returns (entries, problems): `problems` lists human-readable issues for
    *gated* (round) entries that are malformed — e.g. a baseline round entry
    missing its `median_s` key. Malformed non-gated entries are skipped
    silently (microbenches never gate the build), but a gated entry must
    never be dropped on the floor: that would silently stop gating it.
    """
    with open(path) as f:
        doc = json.load(f)
    entries, problems = {}, []
    for e in doc.get("entries", []):
        if not isinstance(e, dict) or "name" not in e:
            continue
        name = e["name"]
        missing = [k for k in ("median_s",) if k not in e]
        if missing:
            if any(s in name for s in GATED_SUBSTRINGS):
                problems.append(
                    f"{path}: round entry {name!r} is missing {', '.join(missing)}"
                )
            continue
        entries[name] = e
    return entries, problems


def prime(current_path, baseline_path):
    # atomic: a verify run killed mid-copy must not leave a truncated
    # baseline that wedges every later gate run
    tmp = baseline_path + ".tmp"
    shutil.copyfile(current_path, tmp)
    os.replace(tmp, baseline_path)
    print(f"bench gate: primed baseline {baseline_path} from {current_path}")


def adopt(current_path, baseline_path, names):
    """Append current entries for `names` to the baseline (atomically)."""
    with open(current_path) as f:
        current_doc = json.load(f)
    with open(baseline_path) as f:
        baseline_doc = json.load(f)
    by_name = {e.get("name"): e for e in current_doc.get("entries", [])}
    baseline_doc.setdefault("entries", []).extend(by_name[n] for n in names)
    tmp = baseline_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(baseline_doc, f)
    os.replace(tmp, baseline_path)
    for n in sorted(names):
        print(f"    ADOPTED  {n} (new round entry; gated from the next run)")


def adopt_counters(baseline_path, updates):
    """Merge new counters ({name: {key: value}}) into existing baseline
    entries (atomically), so counters that appeared after the baseline was
    primed gate from the next run instead of being noted forever."""
    with open(baseline_path) as f:
        doc = json.load(f)
    by_name = {e.get("name"): e for e in doc.get("entries", []) if isinstance(e, dict)}
    for name, kv in updates.items():
        if name in by_name:
            by_name[name].update(kv)
    tmp = baseline_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, baseline_path)
    for name, kv in sorted(updates.items()):
        for k, v in sorted(kv.items()):
            print(f"    ADOPTED  {name} [{k}={v}] (new counter; gated from the next run)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("current")
    ap.add_argument("baseline")
    ap.add_argument("--threshold", type=float, default=1.05)
    ap.add_argument(
        "--results",
        default=None,
        help="experiment store (results/results.jsonl): additionally gate "
        "round entries against the best-ever stored median (trajectory "
        "mode), not just the previous run",
    )
    args = ap.parse_args()

    try:
        current, current_problems = load_entries(args.current)
    except (OSError, ValueError) as e:
        print(f"bench gate: cannot read current results: {e}", file=sys.stderr)
        return 1
    if current_problems:
        for p in current_problems:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            "bench gate: current results are malformed; rerun the hotpath bench",
            file=sys.stderr,
        )
        return 1

    # baseline-independent: fault counters gate before any priming/compare,
    # so even the very first run on a machine fails on a spurious straggler
    faults = fault_problems(current)
    if faults:
        for p in faults:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            "bench gate: fault counters must be zero in a fault-free bench "
            "run (the bench never injects faults); see DESIGN.md §Fault "
            "tolerance",
            file=sys.stderr,
        )
        return 1

    # likewise baseline-independent: scheduler counters must be zero in
    # every balanced entry, and each imbalanced windowed entry must beat
    # its lock-step mate inside the same results file
    sched = sched_problems(current)
    if sched:
        for p in sched:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            "bench gate: scheduler counters must be zero outside the "
            "imbalanced entries (balanced benches run lock-step); see "
            "DESIGN.md §Shard scheduling",
            file=sys.stderr,
        )
        return 1
    imbal = imbalance_problems(current)
    if imbal:
        for p in imbal:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            "bench gate: imbalanced windowed entries must come in strictly "
            "below their lock-step mates; see DESIGN.md §Shard scheduling",
            file=sys.stderr,
        )
        return 1

    # also baseline-independent: each bf16-board entry pairs with its f32
    # mate inside the same results file, so the 0.55x bytes bound holds (or
    # fails) on the very first run too
    halved = bf16_problems(current)
    if halved:
        for p in halved:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            "bench gate: bf16 board entries must ship <= "
            f"{BF16_MAX_RATIO}x the matched f32 entry's board bytes; see "
            "DESIGN.md §bf16 snapshot wire format",
            file=sys.stderr,
        )
        return 1

    # the tracer-overhead acceptance: traced round entries pair with their
    # untraced twins inside the same results file, no baseline involved
    traced = trace_problems(current, args.threshold)
    if traced:
        for p in traced:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            f"bench gate: traced round entries must stay within "
            f"{args.threshold:.2f}x of their untraced mates; see DESIGN.md "
            "§Observability",
            file=sys.stderr,
        )
        return 1

    # trajectory mode: gate against the best-ever stored run, so slow-boil
    # regressions (each within threshold of the last run) still fail
    if args.results is not None:
        if not os.path.exists(args.results):
            print(
                f"bench gate: no experiment store at {args.results} yet; "
                "trajectory gate skipped (this run's append starts it)"
            )
        else:
            try:
                records = load_results(args.results)
            except (OSError, ValueError) as e:
                print(f"bench gate: cannot read experiment store: {e}", file=sys.stderr)
                return 1
            trend = trajectory_problems(current, records, args.threshold)
            if trend:
                for p in trend:
                    print(f"bench gate: {p}", file=sys.stderr)
                print(
                    f"bench gate: round entries regressed past "
                    f"{args.threshold:.2f}x the stored best-ever; see "
                    "EXPERIMENTS.md §Results store",
                    file=sys.stderr,
                )
                return 1
            print(
                f"bench gate: trajectory OK "
                f"({len(records)} stored record(s) in {args.results})"
            )

    try:
        baseline, baseline_problems = load_entries(args.baseline)
    except OSError:
        prime(args.current, args.baseline)
        return 0
    except ValueError as e:
        # corrupt baseline (e.g. an interrupted legacy copy): re-prime
        print(f"bench gate: baseline unreadable ({e}); re-priming", file=sys.stderr)
        prime(args.current, args.baseline)
        return 0
    if baseline_problems:
        # a parseable baseline with a broken round entry is not silently
        # ignorable (that entry would never gate again) and not silently
        # re-primable (that could hide a real regression): fail readably
        for p in baseline_problems:
            print(f"bench gate: {p}", file=sys.stderr)
        print(
            f"bench gate: baseline has malformed round entries; delete "
            f"{args.baseline} to re-prime from the current results",
            file=sys.stderr,
        )
        return 1

    gated = [
        name
        for name in current
        if name in baseline and any(s in name for s in GATED_SUBSTRINGS)
    ]
    # round entries that appeared since the baseline was primed (e.g. a PR
    # added a bench): adopt them into the baseline now so the NEXT run gates
    # them instead of ignoring them forever
    fresh = [
        name
        for name in current
        if name not in baseline and any(s in name for s in GATED_SUBSTRINGS)
    ]
    if fresh:
        adopt(args.current, args.baseline, fresh)
    if not gated:
        print("bench gate: no overlapping round entries to compare; passing")
        return 0

    failed = []
    gained_counters = {}
    for name in sorted(gated):
        if "microkernel" in name:
            # throughput gate: GFLOP/s dropping below baseline/threshold
            # fails (the regression direction is inverted vs. time)
            key = "gflops"
            base_g = baseline[name].get(key)
            cur_g = current[name].get(key)
            if base_g is None or base_g <= 0:
                if cur_g is not None:
                    # baseline predates the counter: adopt for the next run
                    gained_counters.setdefault(name, {})[key] = cur_g
                continue
            if cur_g is None or cur_g <= 0:
                print(
                    f"  REGRESSED       ?x  {name} [{key}]  "
                    f"(counter disappeared from current results)"
                )
                failed.append(f"{name} [{key}]")
                continue
            gratio = base_g / cur_g
            verdict = "OK" if gratio <= args.threshold else "REGRESSED"
            print(
                f"  {verdict:>9}  {gratio:6.3f}x  {name} [{key}]  "
                f"({base_g:.2f} -> {cur_g:.2f} GFLOP/s)"
            )
            if gratio > args.threshold:
                failed.append(f"{name} [{key}]")
            continue

        cur = current[name]["median_s"]
        base = baseline[name]["median_s"]
        ratio = cur / base if base > 0 else float("inf")
        verdict = "OK" if ratio <= args.threshold else "REGRESSED"
        print(f"  {verdict:>9}  {ratio:6.3f}x  {name}  ({base:.6f}s -> {cur:.6f}s)")
        if ratio > args.threshold:
            failed.append(name)

        # the memory-traffic gate: bytes_cloned_per_round is deterministic
        # (assemblies + seals, no timing noise), so it gates whenever the
        # baseline entry carries it
        key = "bytes_cloned_per_round"
        if key in baseline[name]:
            base_b = baseline[name][key]
            if key not in current[name]:
                print(
                    f"  REGRESSED       ?x  {name} [{key}]  "
                    f"(counter disappeared from current results)"
                )
                failed.append(f"{name} [{key}]")
                continue
            cur_b = current[name][key]
            if base_b == 0:
                ok = cur_b == 0
                shown = "0x" if ok else "infx"
            else:
                bratio = cur_b / base_b
                ok = bratio <= args.threshold
                shown = f"{bratio:.3f}x"
            verdict = "OK" if ok else "REGRESSED"
            print(f"  {verdict:>9}  {shown:>6}  {name} [{key}]  ({base_b}B -> {cur_b}B)")
            if not ok:
                failed.append(f"{name} [{key}]")
        elif key in current[name]:
            # baseline predates the counter (e.g. primed before the
            # zero-copy PR): adopt it so the NEXT run gates it, instead of
            # noting it forever
            gained_counters.setdefault(name, {})[key] = current[name][key]

    if gained_counters:
        adopt_counters(args.baseline, gained_counters)

    if failed:
        print(
            f"bench gate: {len(failed)} entr{'y' if len(failed) == 1 else 'ies'} "
            f"regressed past {args.threshold:.2f}x; delete {args.baseline} to "
            "re-prime after an intentional change",
            file=sys.stderr,
        )
        return 1
    print(f"bench gate: OK ({len(gated)} gated entries within {args.threshold:.2f}x)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
